//! Multi-model agent workload generator (paper §4.1 "Inference Setup").
//!
//! Each session runs a four-agent, multi-turn workflow; in each turn all
//! agents are invoked *sequentially* over a largely shared prefix.  Sessions
//! arrive as a Poisson process; once created a session issues its next
//! request immediately upon receiving a response (closed-loop within the
//! session, App. B.1).  Input/output token lengths follow the ReAct /
//! Reflexion statistics reported by Kim et al. (2025) as referenced by the
//! paper — approximated here as lognormal draws around the published means
//! (EXPERIMENTS.md documents the exact parameterization).

use crate::simtime::{secs, SimTime};
use crate::util::rng::Rng;

pub const NUM_AGENTS: usize = 4;

/// One specialized agent (→ one fine-tuned model identity).
#[derive(Debug, Clone)]
pub struct AgentSpec {
    pub name: &'static str,
    /// Model identity 0..NUM_AGENTS (Planner/Coder/… per the paper's ex.).
    pub model: usize,
    pub mean_out_tokens: f64,
    pub cv: f64,
}

/// A workload pattern: agent chain + context geometry.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: &'static str,
    /// Globally shared system prompt (tokens) — identical across sessions.
    pub sys_prompt_tokens: usize,
    /// Session-specific initial prompt length distribution.
    pub init_prompt_mean: f64,
    pub init_prompt_cv: f64,
    pub agents: Vec<AgentSpec>,
    pub turns: usize,
}

/// ReAct: thought → action → observation → reflect, 3 turns.  Context
/// geometry follows agent-trace statistics (Kim et al. 2025): kilotoken
/// initial contexts, observation segments the longest, ~2.1k-token final
/// contexts after 12 calls (decode segments short, prefill-heavy regime).
pub fn react() -> WorkloadSpec {
    WorkloadSpec {
        name: "react",
        sys_prompt_tokens: 160,
        init_prompt_mean: 1024.0,
        init_prompt_cv: 0.25,
        agents: vec![
            AgentSpec { name: "planner", model: 0, mean_out_tokens: 96.0, cv: 0.3 },
            AgentSpec { name: "actor", model: 1, mean_out_tokens: 48.0, cv: 0.3 },
            AgentSpec { name: "observer", model: 2, mean_out_tokens: 128.0, cv: 0.3 },
            AgentSpec { name: "critic", model: 3, mean_out_tokens: 64.0, cv: 0.3 },
        ],
        turns: 3,
    }
}

/// Reflexion: longer verbal-reinforcement segments, heavier contexts
/// (~2.5k-token final contexts).
pub fn reflexion() -> WorkloadSpec {
    WorkloadSpec {
        name: "reflexion",
        sys_prompt_tokens: 200,
        init_prompt_mean: 1280.0,
        init_prompt_cv: 0.25,
        agents: vec![
            AgentSpec { name: "actor", model: 0, mean_out_tokens: 128.0, cv: 0.35 },
            AgentSpec { name: "evaluator", model: 1, mean_out_tokens: 48.0, cv: 0.3 },
            AgentSpec { name: "reflector", model: 2, mean_out_tokens: 160.0, cv: 0.35 },
            AgentSpec { name: "memory", model: 3, mean_out_tokens: 64.0, cv: 0.3 },
        ],
        turns: 3,
    }
}

pub fn workload_by_name(name: &str) -> Option<WorkloadSpec> {
    match name {
        "react" => Some(react()),
        "reflexion" => Some(reflexion()),
        _ => None,
    }
}

/// One model invocation within a session.
#[derive(Debug, Clone, Copy)]
pub struct AgentCall {
    pub model: usize,
    pub out_tokens: usize,
}

/// A fully sampled session: arrival time + the exact call sequence.
#[derive(Debug, Clone)]
pub struct SessionScript {
    pub id: u64,
    pub arrival: SimTime,
    /// Session-specific prompt tokens (after the shared system prompt).
    pub init_prompt_tokens: usize,
    pub calls: Vec<AgentCall>,
}

impl SessionScript {
    /// Total context length after call `i` completes (sys + init + outputs).
    pub fn context_len_after(&self, spec: &WorkloadSpec, i: usize) -> usize {
        spec.sys_prompt_tokens
            + self.init_prompt_tokens
            + self.calls[..=i].iter().map(|c| c.out_tokens).sum::<usize>()
    }

    pub fn total_output_tokens(&self) -> usize {
        self.calls.iter().map(|c| c.out_tokens).sum()
    }
}

/// A complete workload trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub workload: WorkloadSpec,
    pub sessions: Vec<SessionScript>,
    pub horizon: SimTime,
}

/// Sample a trace: Poisson arrivals at `rate_per_s` over `duration_s`.
pub fn generate_trace(spec: &WorkloadSpec, rate_per_s: f64, duration_s: f64, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0x5e551_0ad);
    let mut sessions = Vec::new();
    let mut t = 0.0f64;
    let mut id = 0u64;
    loop {
        t += rng.exp(rate_per_s);
        if t >= duration_s {
            break;
        }
        let mut srng = rng.fork(id);
        let init = srng.lognormal_mean_cv(spec.init_prompt_mean, spec.init_prompt_cv).round() as usize;
        let init = init.clamp(16, 4096);
        let mut calls = Vec::with_capacity(spec.turns * spec.agents.len());
        for _turn in 0..spec.turns {
            for a in &spec.agents {
                let out = srng.lognormal_mean_cv(a.mean_out_tokens, a.cv).round() as usize;
                calls.push(AgentCall { model: a.model, out_tokens: out.clamp(8, 1024) });
            }
        }
        sessions.push(SessionScript { id, arrival: secs(t), init_prompt_tokens: init, calls });
        id += 1;
    }
    Trace { workload: spec.clone(), sessions, horizon: secs(duration_s) }
}

/// Synthetic token ids for the simulator's radix keys.
///
/// The shared system prompt maps to globally identical ids (so *every*
/// session radix-hits it); session-specific content maps to ids unique to
/// (session, position), so cross-session collisions are impossible.
pub mod simtokens {
    /// System-prompt token at position `i`.
    pub fn sys(i: usize) -> u64 {
        1 + i as u64
    }

    /// Session-private token: position `i` of session `sid`'s own content.
    pub fn private(sid: u64, i: usize) -> u64 {
        (1u64 << 40) | (sid << 20) | (i as u64 & 0xFFFFF)
    }

    /// Build the full context key for a session given segment lengths:
    /// sys prompt + (init prompt ++ generated segments) as private ids.
    pub fn context_key(sid: u64, sys_len: usize, private_len: usize) -> Vec<u64> {
        let mut v = Vec::with_capacity(sys_len + private_len);
        for i in 0..sys_len {
            v.push(sys(i));
        }
        for i in 0..private_len {
            v.push(private(sid, i));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let a = generate_trace(&react(), 2.0, 30.0, 7);
        let b = generate_trace(&react(), 2.0, 30.0, 7);
        assert_eq!(a.sessions.len(), b.sessions.len());
        for (x, y) in a.sessions.iter().zip(&b.sessions) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.init_prompt_tokens, y.init_prompt_tokens);
            assert_eq!(x.calls.len(), y.calls.len());
        }
    }

    #[test]
    fn arrival_rate_roughly_matches() {
        let t = generate_trace(&react(), 4.0, 200.0, 1);
        let n = t.sessions.len() as f64;
        assert!((n / 200.0 - 4.0).abs() < 0.6, "rate {}", n / 200.0);
    }

    #[test]
    fn call_structure_matches_spec() {
        let spec = reflexion();
        let t = generate_trace(&spec, 1.0, 50.0, 3);
        for s in &t.sessions {
            assert_eq!(s.calls.len(), spec.turns * spec.agents.len());
            // model identities cycle through the agent chain
            for (i, c) in s.calls.iter().enumerate() {
                assert_eq!(c.model, spec.agents[i % spec.agents.len()].model);
            }
        }
    }

    #[test]
    fn context_grows_monotonically() {
        let spec = react();
        let t = generate_trace(&spec, 1.0, 20.0, 5);
        let s = &t.sessions[0];
        let mut prev = 0;
        for i in 0..s.calls.len() {
            let c = s.context_len_after(&spec, i);
            assert!(c > prev);
            prev = c;
        }
    }

    #[test]
    fn sim_tokens_share_sys_prefix_only() {
        let a = simtokens::context_key(1, 8, 4);
        let b = simtokens::context_key(2, 8, 4);
        assert_eq!(&a[..8], &b[..8], "system prompt shared");
        assert_ne!(&a[8..], &b[8..], "private content distinct");
    }
}

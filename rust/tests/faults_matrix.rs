//! Chaos matrix: every fault type crossed with routing policy and reuse
//! rung, with the per-event `--audit` hooks armed the whole time.
//!
//! The matrix asserts the properties the failure subsystem must hold
//! *everywhere*, not just at the golden fixture's pinned points:
//!
//!   * **Six-channel conservation** — `shipped + reused + reloaded +
//!     forked + relayed + lost == sized context demand`, per class, under
//!     every fault schedule (the demand ledger re-counts torn calls at
//!     re-issue, so the identity is exact even mid-crash).
//!   * **Channel exclusivity** — `lost` is a crash-only channel (link
//!     degradation and stragglers lose nothing), and the reuse channels
//!     stay zero when their rung is off, faults or not.
//!   * **Completion** — under the `static` plane every session still
//!     completes: crashes tear calls down, recovery re-issues them.
//!   * **Determinism** — a faulted run replays byte-identically.

use prefillshare::engine::config::{ClusterConfig, ReuseOpts, SystemKind};
use prefillshare::engine::faults::parse_faults;
use prefillshare::engine::route::RoutePolicy;
use prefillshare::engine::sim::{simulate, ConservationLedger, SimResult};
use prefillshare::workload::{generate_trace, workload_by_name, Trace};

const MATRIX_RATE: f64 = 2.0;
const MATRIX_DURATION: f64 = 30.0;
const MATRIX_SEED: u64 = 42;

fn matrix_trace() -> Trace {
    // Fan-out DAGs engage every reuse channel (delta, relay, fork), so
    // the crash-teardown paths for all of them get exercised.
    let spec = workload_by_name("fanout").expect("fanout workload registered");
    generate_trace(&spec, MATRIX_RATE, MATRIX_DURATION, MATRIX_SEED)
}

fn run_cell(faults: &str, routing: RoutePolicy, reuse: ReuseOpts) -> SimResult {
    let mut cfg = ClusterConfig::paper_default(SystemKind::PrefillShare);
    cfg.routing = routing;
    cfg.reuse = reuse;
    cfg.audit = true;
    cfg.faults = parse_faults(faults).expect("matrix schedule must parse");
    cfg.fault_recovery_s = 8.0;
    simulate(cfg, matrix_trace())
}

/// (schedule, contains a crash) — one row per fault type plus a combined
/// storm that overlaps all three kinds.
const SCHEDULES: [(&str, bool); 6] = [
    ("", false),
    ("crash:p1@8", true),
    ("crash:d0@10", true),
    ("link:l1@5-25x6", false),
    ("straggler:d2@5-25x3", false),
    ("crash:p0@6,crash:d1@12,link:l0@4-20x5,straggler:p2@8-28x2", true),
];

#[test]
fn chaos_matrix_conserves_and_completes() {
    let routings = [RoutePolicy::PrefixAware, RoutePolicy::RoundRobin, RoutePolicy::CacheAware];
    let rungs = ["off", "delta", "delta+relay+fork"];
    let sessions = matrix_trace().sessions.len() as u64;
    let mut crash_lost_total = 0u64;

    for (schedule, has_crash) in SCHEDULES {
        for routing in routings {
            for rung in rungs {
                let reuse = ReuseOpts::by_name(rung).unwrap();
                let r = run_cell(schedule, routing, reuse);
                let cell = format!("faults=[{schedule}] routing={routing:?} reuse={rung}");

                // Six-channel conservation, per class and in total.
                let ledger = ConservationLedger::from_metrics(&r.metrics);
                ledger.assert_covers(&r.metrics.ctx_demand_tokens_by_class, &cell);
                assert_eq!(
                    ledger.total().covered(),
                    r.metrics.ctx_demand_tokens,
                    "{cell}: global identity"
                );

                // Static plane: nothing sheds, everything completes.
                assert_eq!(r.shed_requests, 0, "{cell}: static plane shed");
                assert_eq!(r.repartition_events, 0, "{cell}: static plane repartitioned");
                assert_eq!(
                    r.metrics.sessions_completed, sessions,
                    "{cell}: sessions lost to a fault"
                );
                assert_eq!(
                    r.metrics.faults_injected,
                    parse_faults(schedule).unwrap().len() as u64,
                    "{cell}: schedule miscounted"
                );

                // lost is a crash-only channel.
                if !has_crash {
                    assert_eq!(r.lost_tokens, 0, "{cell}: lost without a crash");
                    assert_eq!(r.recovery_events, 0, "{cell}: recovery without a crash");
                    assert_eq!(
                        r.metrics.wasted_generated_tokens, 0,
                        "{cell}: waste without a crash"
                    );
                } else {
                    assert!(r.recovery_events >= 1, "{cell}: crash never recovered");
                    crash_lost_total += r.lost_tokens;
                }

                // Reuse channels stay dark when their rung is off —
                // faults must not leak tokens into them.
                if !reuse.delta {
                    assert_eq!(r.metrics.decode_reuse_tokens, 0, "{cell}: reuse leak");
                    assert_eq!(r.metrics.host_reload_tokens, 0, "{cell}: reload leak");
                }
                if !reuse.fork {
                    assert_eq!(r.metrics.forked_tokens, 0, "{cell}: fork leak");
                }
                if !reuse.relay {
                    assert_eq!(r.metrics.relayed_tokens, 0, "{cell}: relay leak");
                }
            }
        }
    }

    // Decode crashes must actually destroy KV somewhere in the matrix —
    // otherwise the lost channel (and this whole matrix) is vacuous.
    assert!(crash_lost_total > 0, "no cell ever lost tokens to a crash");
}

#[test]
fn faulted_run_is_deterministic() {
    let reuse = ReuseOpts::by_name("delta+relay+fork").unwrap();
    let a = run_cell(SCHEDULES[5].0, RoutePolicy::CacheAware, reuse);
    let b = run_cell(SCHEDULES[5].0, RoutePolicy::CacheAware, reuse);
    assert_eq!(a.metrics, b.metrics, "faulted replay diverged");
    assert_eq!(a.lost_tokens, b.lost_tokens);
    assert_eq!(a.recovery_events, b.recovery_events);
    assert_eq!(a.recovery_mean_s.to_bits(), b.recovery_mean_s.to_bits());
    assert_eq!(a.goodput_tok_s.to_bits(), b.goodput_tok_s.to_bits());
}

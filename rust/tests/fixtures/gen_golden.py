#!/usr/bin/env python3
"""Reference generator for `golden_fifo.json`, `golden_routes.json`,
`golden_reuse.json`, `golden_fanout.json`, `golden_prefillshare.json` and
`golden_forkrelay.json`.

A line-by-line Python port of the rust cluster simulator's FIFO path
(`engine/sim/` + `engine/sched/fifo.rs`), the DAG workload generator
(`workload.rs`), the radix prefix cache (`kvcache/radix.rs`), the cost model
(`costmodel.rs`) and the PRNG (`util/rng.rs`).  Both implementations are
deterministic integer-microsecond discrete-event simulations over IEEE-754
doubles, so an exact port produces identical counters and (ulp-identical)
float metrics.  The golden regression tests (`tests/sched_determinism.rs`,
`tests/routing_interconnect.rs`, `tests/dag_workloads.rs`) pin the rust
simulator to this file's output.

Beyond the FIFO/prefix-aware default (golden_fifo.json), the port models
the routing subsystem's `round-robin` and `cache-aware` policies and the
contended per-link FIFO interconnect (`engine/sim/interconnect.rs`)
(golden_routes.json), the decode-side session KV residency subsystem
(`--decode-reuse`, `engine/sim/residency.rs`) with delta handoff, LRU
retained-KV eviction and host parking (golden_reuse.json), and —
golden_fanout.json — **DAG-structured sessions with parallel fan-out**:

* a session's calls form a dependency graph; every node issues the moment
  its last parent completes, so sibling calls of one session are in
  flight concurrently (`peak_session_inflight` pins the overlap — the
  fanout scenarios must reach >= 3);
* a node's input context = shared prefix + the outputs of its *ancestor
  cut* in ascending node order, addressed by per-segment radix token ids
  (`workload.rs::simtokens`), so siblings share key prefixes exactly as
  far as their cuts agree;
* retained decode KV carries a segment *signature*; delta handoffs are
  sized against the longest common signature prefix (exact-prefix reuse
  only — a divergent DAG branch reuses nothing past the branch point).
  For chains the signature is always a full prefix, reproducing the
  pre-DAG reuse fixtures bit-for-bit.

golden_prefillshare.json pins the **prefill-module compatibility
classes** (`workload.rs` class map + class-scoped `simtokens` ids): every
token id is scoped to its call's class, so keys of different classes
share no prefix and no KV-reuse surface — radix matching, cache-aware
probing, decode-side residency — can ever match across a module
boundary.  Class 0 is the identity encoding (`(0 << 32) | id == id`), so
the default single-shared-class map reproduces the four pre-class
fixtures byte-for-byte; the fixture's per-model *private* map scenarios
pin per-class counter splits and per-class byte conservation.

golden_forkrelay.json pins the **`--reuse` ladder's two new rungs** (see
`engine/sim/fork.rs` and the relay path in `engine/sim/residency.rs`):

* **CoW fork** (`delta+relay+fork`): same-class sibling nodes issued in
  one batch block-refcount the shared ancestor-cut prefix of their
  contexts (16-token blocks); every non-primary member's `forked` tokens
  arrive by reference — zero transfer time, zero shipped bytes — and the
  group's blocks free only when the last member's handoff completes;
* **decode-KV relay** (`delta+relay`): a fan-out parent's decoded output
  run, still resident on the parent's decode worker (same class, not
  host-parked), covers the child's context as `relayed` tokens instead
  of fresh shipping; the source entry is relay-pinned for the transfer
  (unpin is tolerant — the source's own next call may consume it) and
  relayed KV pays wire time and pages out/in with shipped KV;
* the five-channel conservation identity `shipped + reused + reloaded +
  forked + relayed == context demand` holds per class and in total for
  every scenario, and the full ladder ships strictly fewer tokens than
  plain `delta` on the fanout workload at the pinned seeds.

Decode-tier semantics shared with the rust side (see
`engine/sim/decode_pool.rs`):

* the decode worker's staging gate is an in-flight IO *counter* — a
  stage-in admitted while a stage-out is still draining keeps decode
  compute gated until both copies finish;
* admission's resident cap is *soft* on an idle, empty worker — an
  oversized request (footprint above the whole pool, or above whatever
  unevictable retained KV leaves free) is admitted alone rather than
  parked forever;
* admission discounts the head-of-line request's own pinned residency
  entry *whole* (it is consumed at admit, matching prefix or not).

Regenerate after an *intentional* simulator behaviour change:

    python3 rust/tests/fixtures/gen_golden.py

(or run the rust side with `PREFILLSHARE_BLESS=1 cargo test golden` for
local inspection of a divergence).
"""

import heapq
import json
import math
import os
from collections import deque

MASK = (1 << 64) - 1

# ---------------------------------------------------------------------------
# util/rng.rs — xoshiro256** seeded via SplitMix64
# ---------------------------------------------------------------------------


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def fork(self, stream):
        return Rng(self.next_u64() ^ ((stream * 0x9E3779B97F4A7C15) & MASK))

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def exp(self, rate):
        u = 1.0 - self.f64()
        return -math.log(u) / rate

    def normal(self):
        u1 = 1.0 - self.f64()
        u2 = self.f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def lognormal_mean_cv(self, mean, cv):
        sigma2 = math.log(1.0 + cv * cv)
        mu = math.log(mean) - sigma2 / 2.0
        return math.exp(mu + math.sqrt(sigma2) * self.normal())


def rust_round(x):
    """f64::round — half away from zero (positive inputs only here)."""
    f = math.floor(x)
    return f + 1 if x - f >= 0.5 else f


def clamp(v, lo, hi):
    return max(lo, min(hi, v))


# ---------------------------------------------------------------------------
# simtime.rs
# ---------------------------------------------------------------------------

MICROS = 1_000_000


def secs(t):
    return int(rust_round(t * float(MICROS)))


def to_secs(t):
    return t / float(MICROS)


# ---------------------------------------------------------------------------
# workload.rs — DAG-structured workloads (chains are the degenerate case)
# ---------------------------------------------------------------------------

# Agent tuples: (model, mean_out_tokens, cv, intra-turn parents).  A node
# with no intra-turn parents is a turn root and depends on the previous
# turn's sinks (workload.rs::flatten_parents).

REACT_AGENTS = [(0, 96.0, 0.3, []), (1, 48.0, 0.3, [0]), (2, 128.0, 0.3, [1]), (3, 64.0, 0.3, [2])]

FANOUT_AGENTS = [
    (0, 96.0, 0.3, []),       # planner
    (1, 128.0, 0.3, [0]),     # searcher
    (2, 96.0, 0.3, [0]),      # coder
    (3, 64.0, 0.3, [0]),      # critic
    (0, 96.0, 0.3, [1, 2, 3]),  # joiner
]

REACT = {
    "name": "react",
    "sys_prompt_tokens": 160,
    "init_prompt_mean": 1024.0,
    "init_prompt_cv": 0.25,
    "agents": REACT_AGENTS,
    "turns": 3,
    "variants": [],
}

FANOUT = {
    "name": "fanout",
    "sys_prompt_tokens": 160,
    "init_prompt_mean": 1024.0,
    "init_prompt_cv": 0.25,
    "agents": FANOUT_AGENTS,
    "turns": 3,
    "variants": [],
}

MIXED = {
    "name": "mixed",
    "sys_prompt_tokens": 160,
    "init_prompt_mean": 1024.0,
    "init_prompt_cv": 0.25,
    "agents": REACT_AGENTS,
    "turns": 3,
    # (weight, agents, turns) — drawn per session with one srng.f64().
    "variants": [(0.5, REACT_AGENTS, 3), (0.5, FANOUT_AGENTS, 3)],
}

# workload.rs::debate — 3 parallel proposers per round, then a judge.
DEBATE_AGENTS = [
    (0, 128.0, 0.35, []),
    (1, 128.0, 0.35, []),
    (2, 128.0, 0.35, []),
    (3, 96.0, 0.3, [0, 1, 2]),
]

DEBATE = {
    "name": "debate",
    "sys_prompt_tokens": 200,
    "init_prompt_mean": 1280.0,
    "init_prompt_cv": 0.25,
    "agents": DEBATE_AGENTS,
    "turns": 3,
    "variants": [],
}

WORKLOADS = {"react": REACT, "fanout": FANOUT, "mixed": MIXED, "debate": DEBATE}


def class_of(spec, model):
    """workload.rs::WorkloadSpec::prefill_class_of — missing entries (and
    the empty default map) mean class 0, the identity encoding."""
    classes = spec.get("prefill_classes", [])
    return classes[model] if model < len(classes) else 0


def with_classes(spec, classes):
    s = dict(spec)
    s["prefill_classes"] = list(classes)
    return s


def flatten_parents(agents, turns):
    """workload.rs::flatten_parents — absolute-index parent lists."""
    is_parent = [False] * len(agents)
    for (_m, _mean, _cv, ps) in agents:
        for p in ps:
            is_parent[p] = True
    sinks = [j for j in range(len(agents)) if not is_parent[j]]
    parents = []
    for turn in range(turns):
        base = turn * len(agents)
        for (_m, _mean, _cv, ps) in agents:
            if not ps:
                parents.append([] if turn == 0 else [base - len(agents) + s for s in sinks])
            else:
                parents.append([base + p for p in ps])
    return parents


def generate_trace(spec, rate_per_s, duration_s, seed):
    base_parents = flatten_parents(spec["agents"], spec["turns"])
    var_parents = [flatten_parents(a, t) for (_w, a, t) in spec["variants"]]
    rng = Rng(seed ^ 0x5E5510AD)
    sessions = []
    t = 0.0
    sid = 0
    while True:
        t += rng.exp(rate_per_s)
        if t >= duration_s:
            break
        srng = rng.fork(sid)
        if spec["variants"]:
            total = sum(w for (w, _a, _t) in spec["variants"])
            # workload.rs::pick_variant — the f64-rounding fallback must
            # land on the last *positive-weight* variant, never a
            # zero-weight one; all-zero weights are rejected outright.
            assert total > 0.0, f"workload {spec['name']}: variant weights must sum to > 0"
            u = srng.f64() * total
            vi = max(i for i, (w, _a, _t) in enumerate(spec["variants"]) if w > 0.0)
            for i, (w, _a, _t) in enumerate(spec["variants"]):
                if u < w:
                    vi = i
                    break
                u -= w
            agents, turns, parents = spec["variants"][vi][1], spec["variants"][vi][2], var_parents[vi]
        else:
            agents, turns, parents = spec["agents"], spec["turns"], base_parents
        init = clamp(int(rust_round(srng.lognormal_mean_cv(spec["init_prompt_mean"], spec["init_prompt_cv"]))), 16, 4096)
        calls = []
        for turn in range(turns):
            for j, (model, mean_out, cv, _ps) in enumerate(agents):
                out = clamp(int(rust_round(srng.lognormal_mean_cv(mean_out, cv))), 8, 1024)
                # The class map consumes no RNG draws: same seed + a
                # different map yields an identical session structure.
                calls.append({
                    "model": model,
                    "cls": class_of(spec, model),
                    "out": out,
                    "parents": parents[turn * len(agents) + j],
                })
        sessions.append({"id": sid, "arrival": secs(t), "init": init, "calls": calls})
        sid += 1
    return sessions


def context_key(cls, sid, sys_len, segs):
    """workload.rs::simtokens — class-scoped, segment-addressed token ids
    (segment 0 = init prompt, j + 1 = node j's output).  Class 0 is the
    identity encoding — `(0 << 32) | (1 + i) == 1 + i` — so single-class
    keys are bit-identical to the pre-class fixtures; distinct classes
    share no token id, hence no radix prefix."""
    key = [(cls << 32) | (1 + i) for i in range(sys_len)]
    for (seg, ln) in segs:
        key += [
            (1 << 48) | (cls << 49) | (sid << 28) | ((seg & 0xFFF) << 16) | (i & 0xFFFF)
            for i in range(ln)
        ]
    return key


# ---------------------------------------------------------------------------
# costmodel.rs — A100-80G × LLaMA3.1-8B
# ---------------------------------------------------------------------------

PEAK_FLOPS = 312e12
HBM_BPS = 2.039e12
MEM_BYTES = 80e9
PREFILL_MFU = 0.55
DECODE_MEMBW_EFF = 0.75

N_PARAMS = 8.03e9
N_LAYERS = 32
D_MODEL = 4096
KV_BYTES_PER_TOKEN = float(2 * 32 * 8 * 128 * 2)  # 131072

HANDOFF_BPS = 64e9
HANDOFF_LAT = 0.8e-3
STAGING_BPS = 12e9
STAGING_LAT = 0.3e-3
DECODE_STEP_OVERHEAD = 200e-6
PREFILL_OVERHEAD = 1.5e-3


def weight_bytes():
    return N_PARAMS * 2.0


def prefill_secs(new_tokens, past_tokens):
    if new_tokens == 0:
        return 0.0
    n = float(new_tokens)
    past = float(past_tokens)
    linear = 2.0 * N_PARAMS * n
    visible_sum = n * past + n * (n - 1.0) / 2.0 + n
    attn = 4.0 * float(D_MODEL * N_LAYERS) * visible_sum
    return (linear + attn) / (PEAK_FLOPS * PREFILL_MFU) + PREFILL_OVERHEAD


def decode_step_secs(batch, kv_tokens_total):
    if batch == 0:
        return 0.0
    byts = weight_bytes() + float(kv_tokens_total) * KV_BYTES_PER_TOKEN
    return byts / (HBM_BPS * DECODE_MEMBW_EFF) + DECODE_STEP_OVERHEAD


def handoff_secs(tokens, bps=HANDOFF_BPS):
    byts = float(tokens) * KV_BYTES_PER_TOKEN
    return HANDOFF_LAT + byts / bps


def staging_secs(tokens):
    byts = float(tokens) * KV_BYTES_PER_TOKEN
    return STAGING_LAT + byts / STAGING_BPS


def cluster_config(
    system, routing="prefix", link_contended=False, handoff_bps=HANDOFF_BPS, decode_reuse=False,
    relay=False, fork=False, spec=REACT, faults=(), fault_recovery_s=10.0,
    control_plane="static", slo_ttft_ms=500.0,
):
    usable = max(MEM_BYTES * 0.9 - weight_bytes(), 1e9)
    return {
        "system": system,  # "baseline" | "prefillshare"
        "routing": routing,  # "prefix" | "rr" | "cache"
        "link_contended": link_contended,
        "handoff_bps": handoff_bps,
        # The `--reuse` ladder (config.rs::ReuseOpts): decode_reuse is the
        # `delta` rung; relay and fork are the upper rungs (fork => relay
        # => delta, enforced by the rust side at construction).
        "decode_reuse": decode_reuse,
        "relay": relay,
        "fork": fork,
        "n_prefill_workers": 4,
        "n_models": 4,
        "max_concurrent_sessions": 64,
        "max_decode_batch": 48,
        "prefill_kv_tokens": int(usable * 0.30 / KV_BYTES_PER_TOKEN),
        "decode_kv_tokens": int(usable * 0.20 / KV_BYTES_PER_TOKEN),
        "sys_prompt_tokens": spec["sys_prompt_tokens"],
        # Prefill-module compatibility classes (model -> class); empty =
        # one shared class 0 (the pre-class behaviour the goldens pin).
        "prefill_classes": spec.get("prefill_classes", []),
        # Failure injection + SLO control plane (engine/faults.rs +
        # engine/sim/proxy.rs): an empty schedule and the `static` plane
        # leave every code path byte-identical to the pre-fault port.
        "faults": list(faults),
        "fault_recovery_s": fault_recovery_s,
        "control_plane": control_plane,  # "static" | "slo-shed" | "repartition"
        "slo_ttft_ms": slo_ttft_ms,
    }


# ---------------------------------------------------------------------------
# engine/faults.rs — deterministic fault schedule + control-plane consts
# ---------------------------------------------------------------------------

FAULT_SEED_XOR = 0x00FA075E
# proxy.rs control-plane constants.
TTFT_WINDOW = 64
TTFT_MIN_SAMPLES = 16
REPARTITION_STREAK = 3
ASSIST_FACTOR = 0.5


def fault(kind, tier, idx, start_s, end_s=None, factor=1.0):
    """One FaultSpec (faults.rs): kind is "crash" | "link" | "straggler";
    tier is "p" (prefill worker), "d" (decode worker) or "l" (the decode
    worker's handoff link)."""
    return {"kind": kind, "tier": tier, "idx": idx,
            "start_s": start_s, "end_s": end_s, "factor": factor}


def sample_random(k, n_prefill, n_decode, duration_s, seed):
    """faults.rs::sample_random — every RNG draw mirrored exactly, so the
    same (k, topology, duration, seed) yields the identical schedule on
    both sides."""
    rng = Rng(seed ^ FAULT_SEED_XOR)

    def pick(r, n):
        return min(int(r * n), max(n - 1, 0))

    out = []
    for _ in range(k):
        kind = int(rng.f64() * 3.0)
        if kind == 0:
            # Crash — never a prefill worker when the pool has only one.
            side = rng.f64()
            t = rng.f64()
            if n_prefill >= 2 and side < 0.5:
                tier, idx = "p", pick(t, n_prefill)
            else:
                tier, idx = "d", pick(t, n_decode)
            start = 1.0 + rng.f64() * (duration_s * 0.5)
            out.append(fault("crash", tier, idx, start))
        elif kind == 1:
            tier, idx = "l", pick(rng.f64(), n_decode)
            start = 1.0 + rng.f64() * (duration_s * 0.5)
            ln = duration_s * (0.1 + 0.2 * rng.f64())
            factor = 2.0 + 6.0 * rng.f64()
            out.append(fault("link", tier, idx, start, start + ln, factor))
        else:
            side = rng.f64()
            t = rng.f64()
            if side < 0.5:
                tier, idx = "p", pick(t, n_prefill)
            else:
                tier, idx = "d", pick(t, n_decode)
            start = 1.0 + rng.f64() * (duration_s * 0.5)
            ln = duration_s * (0.1 + 0.2 * rng.f64())
            factor = 1.5 + 2.5 * rng.f64()
            out.append(fault("straggler", tier, idx, start, start + ln, factor))
    return out


def slow_factor(windows, now):
    """faults.rs::slow_factor — product of every covering straggler
    window's factor, None outside all of them (so fault-free float
    arithmetic stays byte-identical to the pre-fault port)."""
    f = None
    for (s, e, fac) in windows:
        if s <= now < e:
            f = fac if f is None else f * fac
    return f


# ---------------------------------------------------------------------------
# kvcache/radix.rs
# ---------------------------------------------------------------------------


class Node:
    __slots__ = ("edge", "children", "parent", "last_access", "locks")

    def __init__(self, edge, children, parent, last_access, locks):
        self.edge = edge
        self.children = children
        self.parent = parent
        self.last_access = last_access
        self.locks = locks


def common_len(a, b):
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class RadixCache:
    def __init__(self, capacity_tokens):
        self.nodes = [Node([], {}, None, 0, 0)]
        self.free_nodes = []
        self.root = 0
        self.clock = 0
        self.resident = 0
        self.capacity = capacity_tokens
        self.evicted_tokens = 0

    def _tick(self):
        self.clock += 1
        return self.clock

    def _new_node(self, node):
        if self.free_nodes:
            nid = self.free_nodes.pop()
            self.nodes[nid] = node
            return nid
        self.nodes.append(node)
        return len(self.nodes) - 1

    def peek_prefix(self, tokens):
        # Read-only descent (kvcache/radix.rs::peek_prefix): no LRU touch,
        # no pinning, no statistics — the cache-aware router's probe.
        cur = self.root
        matched = 0
        while True:
            if matched == len(tokens):
                break
            child = self.nodes[cur].children.get(tokens[matched])
            if child is None:
                break
            elen = len(self.nodes[child].edge)
            common = common_len(self.nodes[child].edge, tokens[matched:])
            matched += common
            if common < elen:
                break
            cur = child
        return matched

    def match_prefix(self, tokens):
        now = self._tick()
        cur = self.root
        matched = 0
        path = [self.root]
        self.nodes[self.root].last_access = now
        while True:
            if matched == len(tokens):
                break
            child = self.nodes[cur].children.get(tokens[matched])
            if child is None:
                break
            elen = len(self.nodes[child].edge)
            common = common_len(self.nodes[child].edge, tokens[matched:])
            self.nodes[child].last_access = now
            if common == elen:
                matched += elen
                path.append(child)
                cur = child
            else:
                matched += common
                path.append(child)
                break
        for n in path:
            self.nodes[n].locks += 1
        return path, matched

    def unlock(self, path):
        # Path replay.  The rust unlock is a token walk (needed only when a
        # pinned edge is split while a chunked job holds its handle); under
        # FIFO a worker has one in-flight job and unlocks before inserting,
        # so no split can happen mid-hold and the two are identical.
        for n in path:
            assert self.nodes[n].locks > 0
            self.nodes[n].locks -= 1

    def insert(self, tokens):
        now = self._tick()
        cur = self.root
        pos = 0
        while True:
            if pos == len(tokens):
                return 0
            child = self.nodes[cur].children.get(tokens[pos])
            if child is None:
                break
            elen = len(self.nodes[child].edge)
            common = common_len(self.nodes[child].edge, tokens[pos:])
            self.nodes[child].last_access = now
            if common == elen:
                pos += elen
                cur = child
            else:
                tail = self.nodes[child].edge[common:]
                self.nodes[child].edge = self.nodes[child].edge[:common]
                grandchildren = self.nodes[child].children
                self.nodes[child].children = {}
                locks = self.nodes[child].locks
                tail_first = tail[0]
                tail_node = self._new_node(Node(tail, grandchildren, child, now, locks))
                for g in self.nodes[tail_node].children.values():
                    self.nodes[g].parent = tail_node
                self.nodes[child].children[tail_first] = tail_node
                pos += common
                cur = child
                break
        remainder = tokens[pos:]
        if not remainder:
            return 0
        need = len(remainder)
        self.nodes[cur].locks += 1
        freed_enough = self._ensure_capacity(need)
        self.nodes[cur].locks -= 1
        take = need if freed_enough else min(max(self.capacity - self.resident, 0), need)
        if take == 0:
            return 0
        leaf = self._new_node(Node(remainder[:take], {}, cur, now, 0))
        self.nodes[cur].children[remainder[0]] = leaf
        self.resident += take
        return take

    def _ensure_capacity(self, need):
        while self.resident + need > self.capacity:
            victim = self._lru_evictable_leaf()
            if victim is None:
                return False
            self._remove_leaf(victim)
        return True

    def _lru_evictable_leaf(self):
        best = None
        for nid, n in enumerate(self.nodes):
            if nid == self.root or not n.edge:
                continue
            if n.children or n.locks > 0:
                continue
            if best is None or n.last_access < best[0]:
                best = (n.last_access, nid)
        return None if best is None else best[1]

    def _remove_leaf(self, nid):
        n = self.nodes[nid]
        first = n.edge[0]
        del self.nodes[n.parent].children[first]
        freed = len(n.edge)
        self.resident -= freed
        self.evicted_tokens += freed
        n.edge = []
        n.parent = None
        self.free_nodes.append(nid)


# ---------------------------------------------------------------------------
# metrics.rs
# ---------------------------------------------------------------------------


class Histogram:
    def __init__(self):
        self.samples = []
        self.sorted = False

    def record(self, v):
        self.samples.append(v)
        self.sorted = False

    def _ensure_sorted(self):
        if not self.sorted:
            self.samples.sort()
            self.sorted = True

    def quantile(self, q):
        if not self.samples:
            return float("nan")
        self._ensure_sorted()
        n = len(self.samples)
        pos = clamp(q, 0.0, 1.0) * float(n - 1)
        lo = int(math.floor(pos))
        hi = int(math.ceil(pos))
        if lo == hi:
            return self.samples[lo]
        w = pos - float(lo)
        return self.samples[lo] * (1.0 - w) + self.samples[hi] * w

    def mean(self):
        # NOTE: sums in the *current* sample order, matching rust (which may
        # or may not have sorted yet depending on prior quantile calls).
        if not self.samples:
            return float("nan")
        acc = 0.0
        for v in self.samples:
            acc += v
        return acc / float(len(self.samples))


# ---------------------------------------------------------------------------
# engine/sim/ — FIFO path (Proxy + PrefillPool + Interconnect + DecodePool)
# over DAG-structured sessions
# ---------------------------------------------------------------------------


def swap_remove(lst, i):
    last = lst.pop()
    if i < len(lst):
        removed = lst[i]
        lst[i] = last
        return removed
    return last


class DecodeReq:
    __slots__ = (
        "sid", "call_idx", "cls", "depth", "ctx_len", "out_tokens", "generated", "issued_at",
        "arrived_at", "ttft_recorded", "was_deferred",
        "shipped_tokens", "reuse_tokens", "host_tokens",
        "forked_tokens", "relayed_tokens", "relay_src", "fork_gid",
        "base", "sig", "is_sink",
    )

    def __init__(self, sid, call_idx, depth, ctx_len, out_tokens, issued_at,
                 shipped_tokens=None, reuse_tokens=0, host_tokens=0,
                 forked_tokens=0, relayed_tokens=0, relay_src=None, fork_gid=None,
                 base=0, sig=(), is_sink=False, cls=0):
        self.sid = sid
        self.call_idx = call_idx
        self.cls = cls
        self.depth = depth
        self.ctx_len = ctx_len
        self.out_tokens = out_tokens
        self.generated = 0
        self.issued_at = issued_at
        self.arrived_at = 0
        self.ttft_recorded = False
        self.was_deferred = False
        # KV tokens the handoff actually shipped (== ctx_len without
        # decode reuse; the session delta with it).
        self.shipped_tokens = ctx_len if shipped_tokens is None else shipped_tokens
        self.reuse_tokens = reuse_tokens
        self.host_tokens = host_tokens
        # Fork/relay cover (sim/mod.rs::on_prefill_done, `--reuse
        # delta+relay[+fork]`): forked tokens reference a sibling group's
        # shared CoW blocks (zero bytes, zero transfer time); relayed
        # tokens copy a fan-out parent's decoded output from its worker's
        # residency entry (they share the transfer window with shipped).
        self.forked_tokens = forked_tokens
        self.relayed_tokens = relayed_tokens
        self.relay_src = relay_src
        self.fork_gid = fork_gid
        # Residency signature of the input context (decode reuse only):
        # base = sys + init, sig = [(node, out_tokens)] over the ancestor
        # cut, ascending.
        self.base = base
        self.sig = list(sig)
        # Sink of its session's graph (no children): no later call can
        # extend its context, so it is never retained on completion.
        self.is_sink = is_sink

    def footprint(self):
        return self.ctx_len + self.out_tokens


def record_pos(slots, idx, v):
    # metrics.rs::record_position — grow-on-demand histogram family.
    while len(slots) <= idx:
        slots.append(Histogram())
    slots[idx].record(v)


class Simulator:
    def __init__(self, cfg, trace):
        self.cfg = cfg
        self.trace = trace
        self.heap = []
        self.seq = 0
        self.now = 0
        n_prefill = cfg["n_models"] if cfg["system"] == "baseline" else cfg["n_prefill_workers"]
        self.prefill = [
            {
                "queue": deque(),
                "busy": None,
                "radix": RadixCache(cfg["prefill_kv_tokens"]),
                "busy_micros": 0,
                # Failure injection (prefill_pool.rs): liveness + passive
                # straggler windows.  Always-alive + empty windows keeps
                # fault-free runs byte-identical.
                "alive": True,
                "slow": [],
            }
            for _ in range(n_prefill)
        ]
        self.decode = [
            {
                "active": [],
                "pending": deque(),
                "staging_in": 0,
                "stepping": False,
                # In-flight host<->GPU copies (decode_pool.rs::io_inflight):
                # decode compute is gated until the count drains to zero.
                "io_inflight": 0,
                "resident": 0,
                "busy_micros": 0,
                "peak_resident": 0,
                # Session residency ledger (engine/sim/residency.rs):
                # sid -> {tokens, base, sig, last_use, on_host, pinned,
                #         pinned_reuse}.
                "residency": {},
                "res_clock": 0,
                "retained_gpu": 0,
                "peak_retained": 0,
                # Failure injection (decode_pool.rs): liveness, crash
                # epoch (stale-event guard), straggler windows, and the
                # repartition-plane assist (at, factor).
                "alive": True,
                "epoch": 0,
                "slow": [],
                "assist": None,
            }
            for _ in range(cfg["n_models"])
        ]
        # Per-session DAG execution state + static per-node facts
        # (sim/mod.rs::SessionState / NodeMeta).
        self.sessions = []
        self.meta = []
        for s in trace:
            calls = s["calls"]
            anc_sets = []
            depths = []
            children = [[] for _ in calls]
            for i, c in enumerate(calls):
                a = set()
                for p in c["parents"]:
                    a.add(p)
                    a |= anc_sets[p]
                anc_sets.append(a)
                depths.append(max((depths[p] + 1 for p in c["parents"]), default=0))
                for p in c["parents"]:
                    children[p].append(i)
            metas = []
            for i in range(len(calls)):
                anc = sorted(anc_sets[i])
                ctx = cfg["sys_prompt_tokens"] + s["init"] + sum(calls[a]["out"] for a in anc)
                metas.append({"anc": anc, "ctx": ctx, "depth": depths[i], "children": children[i]})
            self.meta.append(metas)
            self.sessions.append(
                {
                    "pending": [len(c["parents"]) for c in calls],
                    "remaining": len(calls),
                    "inflight": 0,
                    "arrival": s["arrival"],
                }
            )
        self.admitted = 0
        self.admission_queue = deque()
        # routing + interconnect state (engine/sim/{proxy,interconnect}.rs)
        self.rr = 0
        self.link_free = [0] * cfg["n_models"]
        self.staging_free = [0] * cfg["n_models"]
        # counters
        self.m = {
            "sessions_arrived": 0,
            "sessions_completed": 0,
            "requests_completed": 0,
            "prefix_hit_tokens": 0,
            "prefix_miss_tokens": 0,
            "prefill_computed_tokens": 0,
            "staging_events": 0,
            "staged_tokens": 0,
            "handoffs": 0,
            "handoff_tokens": 0,
            "handoffs_delta": 0,
            "handoff_tokens_delta": 0,
            "decode_reuse_tokens": 0,
            "retained_evictions": 0,
            "retained_evicted_tokens": 0,
            "host_parks": 0,
            "host_reloads": 0,
            "host_reload_tokens": 0,
            "prefill_jobs": 0,
            "prefill_chunks": 0,
            "generated_tokens": 0,
            "peak_session_inflight": 0,
        }
        # Per-prefill-class splits (metrics.rs `*_by_class`, grow-on-demand
        # via bump_class); each list sums to its scalar counterpart.  Kept
        # out of `self.m` so the pre-class fixtures' counter schema (and
        # bytes) stays untouched — only golden_prefillshare.json pins them.
        self.by_class = {
            "prefix_hit_tokens": [],
            "prefix_miss_tokens": [],
            "handoff_tokens": [],
            "decode_reuse_tokens": [],
            "host_reload_tokens": [],
        }
        # Fork/relay counters (metrics.rs forked_tokens/relayed_tokens/
        # handoffs_forked/handoffs_relayed) and their per-class splits.
        # Kept out of `self.m` / `self.by_class` so the five pre-forkrelay
        # fixtures' counter schema (and bytes) stays untouched — only
        # golden_forkrelay.json pins them.
        self.forkrelay = {
            "forked_tokens": 0,
            "relayed_tokens": 0,
            "handoffs_forked": 0,
            "handoffs_relayed": 0,
        }
        self.forkrelay_by_class = {"forked_tokens": [], "relayed_tokens": []}
        # CoW fork registry (engine/sim/fork.rs): a refcounted block pool
        # capped at the decode worker KV budget, 16 tokens per block.
        # Only block *counts* are observable (alloc fails iff the free
        # count is short), so the free list itself is not modelled.
        self.fork_capacity = max(-(-cfg["decode_kv_tokens"] // 16), 1)
        self.fork_used = 0
        self.fork_groups = {}   # gid -> [n_blocks, live_refs]
        self.fork_pending = {}  # (sid, node) -> (gid, shared_tokens, primary)
        self.next_gid = 0
        self.session_latency = Histogram()
        self.ttft = Histogram()
        self.request_latency = Histogram()
        self.queue_delay = Histogram()
        self.decode_qd = Histogram()
        self.handoff_wait = Histogram()
        self.ttft_pos = []
        self.ttft_depth = []
        self.tput_first = None
        self.tput_last = None
        self.last_completion = 0
        self.first_arrival = MASK  # SimTime::MAX
        # -- failure injection + control plane (faults.rs, sim/mod.rs,
        #    proxy.rs).  With an empty schedule and the `static` plane,
        #    epochs stay 0, every worker stays alive and none of this
        #    state alters a single event.
        self.faults = list(cfg.get("faults", ()))
        self.prefill_epoch = [0] * n_prefill
        # Per-decode-worker handoff-link degradation windows
        # (interconnect.rs::Link::slow); staging links are never degraded.
        self.link_slow = [[] for _ in range(cfg["n_models"])]
        for f in self.faults:
            start = secs(f["start_s"])
            end = secs(f["end_s"]) if f["end_s"] is not None else MASK
            if f["kind"] == "link":
                self.link_slow[f["idx"]].append((start, end, f["factor"]))
            elif f["kind"] == "straggler":
                pool = self.prefill if f["tier"] == "p" else self.decode
                pool[f["idx"]]["slow"].append((start, end, f["factor"]))
        # Open crash records: a crash is "recovered" once every call it
        # tore down has completed (sim/mod.rs::OpenCrash).
        self.open_crashes = []  # {idx, at, tier, target, torn:set}
        self.recovery_times = []
        self.reissue = [set() for _ in range(cfg["n_models"])]
        self.flex_lent = False
        self.flex_target = None
        self.plane = cfg.get("control_plane", "static")
        self.slo_s = cfg.get("slo_ttft_ms", 500.0) / 1000.0
        self.ttft_recent = deque()  # proxy.rs::SloShedPlane window
        self.plane_streak = 0
        # Fault counters (metrics.rs) kept out of `self.m` so the six
        # pre-fault fixtures' counter schema (and bytes) stays untouched —
        # only golden_faults.json pins them.
        self.faultm = {
            "faults_injected": len(self.faults),
            "shed_requests": 0,
            "lost_tokens": 0,
            "wasted_generated_tokens": 0,
            "repartition_events": 0,
        }
        self.lost_by_class = []
        # Per-event audit ledgers (sim/mod.rs --audit); previously lazily
        # created at the first handoff, now owned here so the lost channel
        # can post before any handoff happens.
        self.audit_demand = {}
        self.audit_host_sized = {}

    # -- event queue ------------------------------------------------------

    def schedule(self, at, ev):
        self.seq += 1
        heapq.heappush(self.heap, (max(at, self.now), self.seq, ev))

    def schedule_in(self, delay, ev):
        self.schedule(self.now + delay, ev)

    def run(self):
        for sid, s in enumerate(self.trace):
            self.schedule(s["arrival"], ("arrive", sid))
        # Crash faults become events; link/straggler windows are passive
        # (installed in __init__).  Only the repartition plane ticks.
        for i, f in enumerate(self.faults):
            if f["kind"] == "crash":
                self.schedule(secs(f["start_s"]), ("fault", i))
        if self.plane == "repartition":
            self.schedule(secs(1.0), ("plane_tick",))
        while self.heap:
            t, _, ev = heapq.heappop(self.heap)
            self.now = t
            kind = ev[0]
            # Epoch guards (sim/mod.rs::handle): worker-progress events of
            # a dead incarnation are dropped; request-carrying events of a
            # dead incarnation tear their request down instead.
            if kind == "arrive":
                self.on_arrival(ev[1])
            elif kind == "prefill_done":
                if ev[2] == self.prefill_epoch[ev[1]]:
                    self.on_prefill_done(ev[1])
            elif kind == "handoff_done":
                if ev[3] == self.decode[ev[2]]["epoch"]:
                    self.on_handoff_done(ev[1], ev[2])
                else:
                    self.teardown_req(ev[1], ev[2])
            elif kind == "stage_in":
                if ev[3] == self.decode[ev[2]]["epoch"]:
                    self.on_stage_in_done(ev[1], ev[2])
                else:
                    self.teardown_req(ev[1], ev[2])
            elif kind == "stage_out":
                if ev[2] == self.decode[ev[1]]["epoch"]:
                    self.on_stage_out_done(ev[1])
            elif kind == "step_done":
                if ev[2] == self.decode[ev[1]]["epoch"]:
                    self.on_decode_step_done(ev[1])
            elif kind == "fault":
                self.on_fault(ev[1])
            elif kind == "recover":
                self.on_recover(ev[1])
            elif kind == "plane_tick":
                self.on_plane_tick()
            elif kind == "flex_revive":
                if not self.prefill[ev[1]]["alive"]:
                    self.prefill[ev[1]]["alive"] = True
                    self.try_start_prefill(ev[1])
        return self.finish()

    # -- sessions ---------------------------------------------------------

    def on_arrival(self, sid):
        self.m["sessions_arrived"] += 1
        self.first_arrival = min(self.first_arrival, self.now)
        if not self.plane_admit():
            # SLO guard (proxy.rs::SloShedPlane): turned away at the
            # door, never enters the system (still counts as arrived).
            self.faultm["shed_requests"] += 1
            return
        if self.admitted < self.cfg["max_concurrent_sessions"]:
            self.admit(sid)
        else:
            self.admission_queue.append(sid)

    def plane_admit(self):
        # proxy.rs::ControlPlane::admit — only `slo-shed` ever sheds, and
        # only once the sliding TTFT window has enough samples.
        if self.plane != "slo-shed" or len(self.ttft_recent) < TTFT_MIN_SAMPLES:
            return True
        s = sorted(self.ttft_recent)
        p95 = s[(len(s) * 95 + 99) // 100 - 1]
        return p95 <= self.slo_s

    def plane_record_ttft(self, t):
        if self.plane != "slo-shed":
            return
        self.ttft_recent.append(t)
        if len(self.ttft_recent) > TTFT_WINDOW:
            self.ttft_recent.popleft()

    def admit(self, sid):
        self.admitted += 1
        self.start_session(sid)

    def start_session(self, sid):
        # Issue every root of the call graph, ascending node order.
        roots = [i for i, c in enumerate(self.trace[sid]["calls"]) if not c["parents"]]
        self.issue_batch(sid, roots)

    def context_sig(self, sid, node):
        # sim/mod.rs::context_sig — (node, out_tokens) per ancestor, ascending.
        s = self.trace[sid]
        return [(a, s["calls"][a]["out"]) for a in self.meta[sid][node]["anc"]]

    def issue_batch(self, sid, nodes):
        # sim/mod.rs::issue_batch — under `--reuse delta+relay+fork`,
        # sibling nodes of one prefill class issued in the same batch open
        # a CoW fork group over their shared ancestor-cut prefix *before*
        # any of them is issued (class groups open in ascending class
        # order; members stay in ascending node order).
        if self.cfg.get("fork") and len(nodes) >= 2:
            s = self.trace[sid]
            base = self.cfg["sys_prompt_tokens"] + s["init"]
            by_cls = {}
            for n in nodes:
                by_cls.setdefault(s["calls"][n]["cls"], []).append(n)
            for cls in sorted(by_cls):
                members = by_cls[cls]
                if len(members) < 2:
                    continue
                lcp = self.context_sig(sid, members[0])
                for m in members[1:]:
                    other = self.context_sig(sid, m)
                    common = 0
                    for a, b in zip(lcp, other):
                        if a != b:
                            break
                        common += 1
                    lcp = lcp[:common]
                shared = base + sum(ln for (_n, ln) in lcp)
                self.fork_open(sid, members, shared)
        for n in nodes:
            self.issue_node(sid, n)

    def fork_open(self, sid, members, shared_tokens):
        # fork.rs::ForkRegistry::open — allocation failure (tiny pool)
        # degrades to no fork: no pending records, every member ships.
        n_blocks = -(-shared_tokens // 16)  # BlockPool::blocks_for
        if self.fork_used + n_blocks > self.fork_capacity:
            return False
        self.fork_used += n_blocks
        gid = self.next_gid
        self.next_gid += 1
        self.fork_groups[gid] = [n_blocks, len(members)]
        for i, node in enumerate(members):
            assert (sid, node) not in self.fork_pending, "node forked twice"
            self.fork_pending[(sid, node)] = (gid, shared_tokens, i == 0)
        return True

    def fork_drop_ref(self, gid):
        # fork.rs::drop_ref — one member's handoff completed; the last
        # drop frees the group's blocks.
        g = self.fork_groups[gid]
        assert g[1] > 0, "dropping a ref on a closed fork group"
        g[1] -= 1
        if g[1] == 0:
            self.fork_used -= g[0]
            del self.fork_groups[gid]

    def relay_probe(self, w, sid, cls, ctx_sig):
        # residency.rs::relay_probe — observation-only sizing of worker
        # w's entry for sid: base + signature LCP.  Class-mismatched,
        # host-parked and absent entries source nothing (and unlike
        # pin_for_handoff a foreign-class entry is NOT dropped).
        e = self.decode[w]["residency"].get(sid)
        if e is None or e["cls"] != cls or e["on_host"]:
            return 0
        r = e["base"]
        for have, need in zip(e["sig"], ctx_sig):
            if have != need:
                break
            r += have[1]
        return r

    def bump_class(self, key, cls, tokens):
        slots = self.by_class[key]
        while len(slots) <= cls:
            slots.append(0)
        slots[cls] += tokens

    def node_key(self, sid, node):
        s = self.trace[sid]
        meta = self.meta[sid][node]
        segs = [(0, s["init"])] + [(a + 1, s["calls"][a]["out"]) for a in meta["anc"]]
        return context_key(s["calls"][node]["cls"], sid, self.cfg["sys_prompt_tokens"], segs)

    def issue_node(self, sid, node):
        st = self.sessions[sid]
        st["inflight"] += 1
        self.m["peak_session_inflight"] = max(self.m["peak_session_inflight"], st["inflight"])
        meta = self.meta[sid][node]
        job = {
            "sid": sid,
            "call_idx": node,
            "model": self.trace[sid]["calls"][node]["model"],
            "cls": self.trace[sid]["calls"][node]["cls"],
            "ctx_len": meta["ctx"],
            "issued_at": self.now,
            "key": self.node_key(sid, node),
        }
        w = self.route_alive(job)
        self.prefill[w]["queue"].append(job)
        self.try_start_prefill(w)

    def route_alive(self, job):
        # sim/mod.rs::route_alive — the routing policy picks as if the
        # pool were whole (its RNG/tie-break sequence is preserved), then
        # the choice advances to the first alive worker, wrapping.
        if self.cfg["system"] == "baseline":
            w0 = job["model"]
        else:
            w0 = self.route(job)
        n = len(self.prefill)
        for off in range(n):
            w = (w0 + off) % n
            if self.prefill[w]["alive"]:
                return w
        return w0

    def reissue_call(self, sid, node):
        # sim/mod.rs::reissue_call — the call never completed, so the
        # session's inflight/remaining counters still carry it; only the
        # prefill job is rebuilt (its latency clock restarts at `now`).
        job = {
            "sid": sid,
            "call_idx": node,
            "model": self.trace[sid]["calls"][node]["model"],
            "cls": self.trace[sid]["calls"][node]["cls"],
            "ctx_len": self.meta[sid][node]["ctx"],
            "issued_at": self.now,
            "key": self.node_key(sid, node),
        }
        w = self.route_alive(job)
        self.prefill[w]["queue"].append(job)
        self.try_start_prefill(w)

    def outstanding(self, w):
        # prefill_pool.rs: queued remaining (full ctx before first
        # dispatch) + the busy whole-job unit's remainder.
        pw = self.prefill[w]
        t = sum(j["ctx_len"] for j in pw["queue"])
        if pw["busy"] is not None:
            job, _path, matched = pw["busy"]
            t += job["ctx_len"] - matched
        return t

    def route(self, job):
        # engine/route/: prefix_aware.rs / round_robin.rs / cache_aware.rs
        n = len(self.prefill)
        pol = self.cfg.get("routing", "prefix")
        if pol == "rr":
            self.rr = (self.rr + 1) % n
            return self.rr
        if pol == "cache":
            scores = [pw["radix"].peek_prefix(job["key"]) for pw in self.prefill]
            best = max(scores)
            # Class-affinity home (route/*.rs): sessions of different
            # compatibility classes get different tie-break homes, so
            # same-class traffic clusters where its warm prefixes live.
            home = (job["sid"] + job["cls"]) % n
            if best * 2 < job["ctx_len"]:
                # Weak match (shared sys prefix only): least-loaded
                # placement; ties prefer the session's class home.
                outs = [self.outstanding(i) for i in range(n)]
                m = min(outs)
                if outs[home] == m:
                    return home
                return outs.index(m)
            if scores[home] == best:
                return home
            pick = None
            for i, s in enumerate(scores):
                if s != best:
                    continue
                if pick is None or self.outstanding(i) < self.outstanding(pick):
                    pick = i
            return pick
        return (job["sid"] + job["cls"]) % n  # prefix-aware class-home pinning

    # -- prefill ----------------------------------------------------------

    def try_start_prefill(self, w):
        pw = self.prefill[w]
        if pw["busy"] is not None or not pw["queue"] or not pw["alive"]:
            return
        job = pw["queue"].popleft()
        path, matched = pw["radix"].match_prefix(job["key"])
        new_tokens = job["ctx_len"] - matched
        self.m["prefix_hit_tokens"] += matched
        self.m["prefix_miss_tokens"] += new_tokens
        self.bump_class("prefix_hit_tokens", job["cls"], matched)
        self.bump_class("prefix_miss_tokens", job["cls"], new_tokens)
        self.m["prefill_computed_tokens"] += new_tokens
        self.m["prefill_jobs"] += 1
        self.queue_delay.record(to_secs(self.now - job["issued_at"]))
        self.m["prefill_chunks"] += 1
        cost = prefill_secs(new_tokens, matched)
        f = slow_factor(pw["slow"], self.now)
        if f is not None:
            # Straggler GPU (prefill_pool.rs): the float cost is inflated
            # before rounding so fault-free math stays byte-identical.
            cost *= f
        dur_us = secs(cost)
        pw["busy_micros"] += dur_us
        pw["busy"] = (job, path, matched)
        self.schedule_in(dur_us, ("prefill_done", w, self.prefill_epoch[w]))

    def on_prefill_done(self, w):
        pw = self.prefill[w]
        job, path, _matched = pw["busy"]
        pw["busy"] = None
        pw["radix"].unlock(path)
        pw["radix"].insert(job["key"])
        sid, node = job["sid"], job["call_idx"]
        call = self.trace[sid]["calls"][node]
        model, out_tokens = call["model"], call["out"]
        meta = self.meta[sid][node]
        if not self.decode[model]["alive"]:
            # sim/mod.rs::on_prefill_done dead-target branch: the freshly
            # computed KV has nowhere to land.  No handoff is sized; a
            # balanced demand/lost pair keeps the conservation identity
            # and the call re-issues when the worker recovers.
            ctx = job["ctx_len"]
            cls = job["cls"]
            self.audit_demand[cls] = self.audit_demand.get(cls, 0) + ctx
            self.faultm["lost_tokens"] += ctx
            self.bump_lost(cls, ctx)
            p = self.fork_pending.pop((sid, node), None)
            if p is not None:
                self.fork_drop_ref(p[0])
            for oc in reversed(self.open_crashes):
                if oc["tier"] == "d" and oc["target"] == model:
                    oc["torn"].add((sid, node))
                    break
            self.reissue[model].add((sid, node))
            self.try_start_prefill(w)
            return
        # Decode reuse (sim/mod.rs::on_prefill_done): the decode worker may
        # retain part of the session's context — size the delta against the
        # longest common prefix of the retained signature and this node's
        # context signature, pin the entry, ship only the delta.
        reuse_tokens = host_tokens = 0
        base = 0
        sig = []
        if self.cfg.get("decode_reuse"):
            base = self.cfg["sys_prompt_tokens"] + self.trace[sid]["init"]
            sig = [(a, self.trace[sid]["calls"][a]["out"]) for a in meta["anc"]]
            e = self.decode[model]["residency"].get(sid)
            if e is not None and e["cls"] != call["cls"]:
                # residency.rs class boundary: KV retained under another
                # prefill module is unusable — drop the stale entry rather
                # than reuse across the class boundary.
                if not e["on_host"]:
                    self.decode[model]["retained_gpu"] -= e["tokens"]
                del self.decode[model]["residency"][sid]
                e = None
            if e is not None:
                r = e["base"]
                for have, need in zip(e["sig"], sig):
                    if have == need:
                        r += have[1]
                    else:
                        break
                e["pinned"] = True
                e["pinned_reuse"] = r
                if e["on_host"]:
                    host_tokens = r
                else:
                    reuse_tokens = r
        own = reuse_tokens + host_tokens
        # CoW fork cover (sim/mod.rs::on_prefill_done): a non-primary
        # fork-group member references the shared span [own, shared)
        # through the group's blocks — zero bytes, zero transfer time.
        # The pending record is consumed unconditionally (it only exists
        # when fork is on).
        forked = 0
        fork_gid = None
        p = self.fork_pending.pop((sid, node), None)
        if p is not None:
            gid, shared, primary = p
            fork_gid = gid
            if not primary:
                forked = max(min(shared, job["ctx_len"]) - own, 0)
        # Decode-KV relay: cover the best single fan-out parent's decoded
        # output from the residency entry on *that parent's* decode
        # worker, clipped to the parent's own output run.  Strict max;
        # ties keep the lowest parent (parents iterate ascending).
        relayed = 0
        relay_src = None
        if self.cfg.get("relay"):
            cov = own + forked
            for par in call["parents"]:
                if len(self.meta[sid][par]["children"]) < 2:
                    continue
                src_w = self.trace[sid]["calls"][par]["model"]
                r_src = self.relay_probe(src_w, sid, call["cls"], sig)
                if r_src == 0:
                    continue
                run_start = base
                for a in meta["anc"]:
                    if a >= par:
                        break
                    run_start += self.trace[sid]["calls"][a]["out"]
                run_end = run_start + self.trace[sid]["calls"][par]["out"]
                cand = max(min(run_end, r_src) - max(run_start, cov), 0)
                if cand > relayed:
                    relayed = cand
                    relay_src = src_w
            if relay_src is not None:
                # Shield the source entry from LRU reclaim until the
                # relay copy lands (unpinned at handoff_done).
                self.decode[relay_src]["residency"][sid]["relay_pins"] += 1
        shipped = job["ctx_len"] - own - forked - relayed
        # Per-event conservation (sim/mod.rs::audit_handoff, --audit): the
        # sized split is non-negative, exclusive (GPU-retained XOR
        # host-parked) and exhaustive against this call's context demand
        # across all five supply channels.
        assert shipped >= 0, (sid, node, shipped)
        assert reuse_tokens == 0 or host_tokens == 0, (sid, node, reuse_tokens, host_tokens)
        assert shipped + reuse_tokens + host_tokens + forked + relayed == job["ctx_len"], (sid, node)
        if relayed:
            # A relayed span never exceeds any fan-out parent's decoded
            # output (audit_handoff check (d)).
            assert relayed <= max(
                self.trace[sid]["calls"][par]["out"]
                for par in call["parents"]
                if len(self.meta[sid][par]["children"]) >= 2
            ), (sid, node, relayed)
        req = DecodeReq(
            sid, node, meta["depth"], job["ctx_len"], out_tokens, job["issued_at"],
            shipped_tokens=shipped, reuse_tokens=reuse_tokens, host_tokens=host_tokens,
            forked_tokens=forked, relayed_tokens=relayed, relay_src=relay_src, fork_gid=fork_gid,
            base=base, sig=sig,
            is_sink=not meta["children"], cls=job["cls"],
        )
        self.m["handoffs"] += 1
        self.m["handoff_tokens"] += shipped
        self.bump_class("handoff_tokens", job["cls"], shipped)
        if reuse_tokens + host_tokens > 0:
            self.m["handoffs_delta"] += 1
            self.m["handoff_tokens_delta"] += shipped
            self.m["decode_reuse_tokens"] += reuse_tokens
            self.bump_class("decode_reuse_tokens", job["cls"], reuse_tokens)
        if forked > 0:
            self.forkrelay["handoffs_forked"] += 1
            self.forkrelay["forked_tokens"] += forked
            slots = self.forkrelay_by_class["forked_tokens"]
            while len(slots) <= job["cls"]:
                slots.append(0)
            slots[job["cls"]] += forked
        if relayed > 0:
            self.forkrelay["handoffs_relayed"] += 1
            self.forkrelay["relayed_tokens"] += relayed
            slots = self.forkrelay_by_class["relayed_tokens"]
            while len(slots) <= job["cls"]:
                slots.append(0)
            slots[job["cls"]] += relayed
        # Per-event per-class identity (--audit): host reload is charged
        # later, at decode admission, so track the *sized* host tokens here
        # and require shipped + reused + sized + lost to cover the class
        # demand at every handoff (not only at end of run).
        cls = job["cls"]
        self.audit_demand[cls] = self.audit_demand.get(cls, 0) + job["ctx_len"]
        self.audit_host_sized[cls] = self.audit_host_sized.get(cls, 0) + host_tokens
        shipped_c = pad_get(self.by_class["handoff_tokens"], cls)
        reused_c = pad_get(self.by_class["decode_reuse_tokens"], cls)
        forked_c = pad_get(self.forkrelay_by_class["forked_tokens"], cls)
        relayed_c = pad_get(self.forkrelay_by_class["relayed_tokens"], cls)
        lost_c = pad_get(self.lost_by_class, cls)
        assert (
            shipped_c + reused_c + self.audit_host_sized[cls] + forked_c + relayed_c + lost_c
            == self.audit_demand[cls]
        ), (sid, node, "class", cls, "lost tokens at handoff")
        # Interconnect (engine/sim/interconnect.rs): FIFO per ingress link
        # when contended, fire-and-forget otherwise.  Shipped and relayed
        # tokens both occupy the transfer window; forked tokens are a CoW
        # block reference and cost no transfer time.  A degraded link
        # stretches the transfer, but the queue-wait metric is still
        # recorded against the undegraded duration (interconnect.rs).
        dur = secs(handoff_secs(shipped + relayed, self.cfg.get("handoff_bps", HANDOFF_BPS)))
        now = self.now
        ddur = self.link_degraded(model, now, dur)
        start = max(now, self.link_free[model]) if self.cfg.get("link_contended") else now
        end = start + ddur
        self.link_free[model] = max(self.link_free[model], end)
        self.handoff_wait.record(to_secs(end - dur - now))
        self.schedule(end, ("handoff_done", req, model, self.decode[model]["epoch"]))
        self.try_start_prefill(w)

    def link_degraded(self, w, now, dur):
        # interconnect.rs::Link::degraded — each covering window inflates
        # the duration in turn, rounding half away from zero; staging
        # links are deliberately unaffected.
        for (s, e, f) in self.link_slow[w]:
            if s <= now < e:
                dur = int(rust_round(dur * f))
        return dur

    def bump_lost(self, cls, tokens):
        slots = self.lost_by_class
        while len(slots) <= cls:
            slots.append(0)
        slots[cls] += tokens

    # -- decode -----------------------------------------------------------

    def stage_transfer(self, w, dur):
        # interconnect.rs staging link: FIFO when contended, fire-and-forget
        # otherwise.  Several copies can be on the link at once (a stage-in
        # admitted while a stage-out drains, retained-KV host-parks); the
        # io_inflight counter gates decode compute until all of them finish.
        start = max(self.now, self.staging_free[w]) if self.cfg.get("link_contended") else self.now
        end = start + dur
        self.staging_free[w] = max(self.staging_free[w], end)
        return end

    def on_handoff_done(self, req, w):
        # Relay source unpin (tolerant — the source session's own next
        # call may have consumed the entry mid-relay) and fork-group ref
        # drop happen before admission (sim/mod.rs::on_handoff_done).
        if req.relay_src is not None:
            e = self.decode[req.relay_src]["residency"].get(req.sid)
            if e is not None:
                e["relay_pins"] = max(e["relay_pins"] - 1, 0)
            # Cleared rather than kept (Rust `take()`): a later
            # crash-teardown of this request must not release either
            # reference a second time.
            req.relay_src = None
        if req.fork_gid is not None:
            self.fork_drop_ref(req.fork_gid)
            req.fork_gid = None
        req.arrived_at = self.now
        self.decode[w]["pending"].append(req)
        self.try_admit_decode(w)
        self.maybe_step(w)

    def evict_one(self, w):
        # decode_pool.rs::evict_one — reclaim one LRU retained session;
        # discard vs host-park priced by the cost model.
        dw = self.decode[w]
        best = None
        for sid, e in dw["residency"].items():
            # Handoff-pinned, host-parked and in-flight relay-source
            # entries are all shielded (residency.rs::lru_victim).
            if e["pinned"] or e["on_host"] or e["relay_pins"] > 0:
                continue
            key = (e["last_use"], sid)
            if best is None or key < best[0]:
                best = (key, sid, e)
        if best is None:
            return False
        _, sid, e = best
        tokens = e["tokens"]
        self.m["retained_evictions"] += 1
        self.m["retained_evicted_tokens"] += tokens
        rehandoff = handoff_secs(tokens, self.cfg.get("handoff_bps", HANDOFF_BPS))
        round_trip = 2.0 * staging_secs(tokens)
        if round_trip < rehandoff:
            e["on_host"] = True
            dw["retained_gpu"] -= tokens
            dw["io_inflight"] += 1
            self.m["host_parks"] += 1
            self.m["staging_events"] += 1
            self.m["staged_tokens"] += tokens
            end = self.stage_transfer(w, secs(staging_secs(tokens)))
            self.schedule(end, ("stage_out", w, dw["epoch"]))
        else:
            del dw["residency"][sid]
            dw["retained_gpu"] -= tokens
        return True

    def entry_gpu_tokens(self, dw, sid):
        # residency.rs::entry_gpu_tokens — the front's own pinned entry is
        # discounted whole: admission consumes it, matching prefix or not.
        e = dw["residency"].get(sid)
        return e["tokens"] if e is not None and not e["on_host"] else 0

    def try_admit_decode(self, w):
        cap = self.cfg["decode_kv_tokens"]
        if not self.decode[w]["alive"]:
            return
        while True:
            dw = self.decode[w]
            # Eviction pre-pass (decode_pool.rs::try_admit): reclaim
            # retained KV until the front fits, so the admission decision
            # (and its soft-cap override) sees post-eviction occupancy.
            if self.cfg.get("decode_reuse"):
                while dw["pending"]:
                    if len(dw["active"]) + dw["staging_in"] >= self.cfg["max_decode_batch"]:
                        break
                    front = dw["pending"][0]
                    need = dw["resident"] + front.footprint() + (
                        dw["retained_gpu"] - self.entry_gpu_tokens(dw, front.sid)
                    )
                    if need <= cap or not self.evict_one(w):
                        break
            if len(dw["active"]) + dw["staging_in"] >= self.cfg["max_decode_batch"]:
                return
            if not dw["pending"]:
                return
            front = dw["pending"][0]
            fp = front.footprint()
            retained = dw["retained_gpu"] - self.entry_gpu_tokens(dw, front.sid)
            force = retained + fp > cap and dw["resident"] == 0
            if dw["resident"] + retained + fp > cap and not force:
                if not front.was_deferred and dw["io_inflight"] == 0:
                    front.was_deferred = True
                    dw["io_inflight"] += 1
                    self.m["staging_events"] += 1
                    # Relayed KV arrived over the wire like shipped KV, so
                    # it pages out (and back in) with it; forked KV is
                    # shared-by-reference and never staged.
                    park = front.shipped_tokens + front.relayed_tokens
                    self.m["staged_tokens"] += park
                    end = self.stage_transfer(w, secs(staging_secs(park)))
                    self.schedule(end, ("stage_out", w, dw["epoch"]))
                return
            req = dw["pending"].popleft()
            dw["resident"] += fp
            dw["peak_resident"] = max(dw["peak_resident"], dw["resident"])
            self.decode_qd.record(to_secs(self.now - req.arrived_at))
            if self.cfg.get("decode_reuse"):
                e = dw["residency"].pop(req.sid, None)
                if e is not None and not e["on_host"]:
                    dw["retained_gpu"] -= e["tokens"]
            reload = req.host_tokens + (
                (req.shipped_tokens + req.relayed_tokens) if req.was_deferred else 0
            )
            if reload > 0:
                dw["staging_in"] += 1
                dw["io_inflight"] += 1
                self.m["staging_events"] += 1
                self.m["staged_tokens"] += reload
                if req.host_tokens > 0:
                    self.m["host_reloads"] += 1
                    self.m["host_reload_tokens"] += req.host_tokens
                    self.bump_class("host_reload_tokens", req.cls, req.host_tokens)
                    # Per-event (--audit mirror, sim/mod.rs::audit_handoff):
                    # a class never reloads more than its handoffs sized for
                    # the host path.
                    assert pad_get(self.by_class["host_reload_tokens"], req.cls) <= \
                        self.audit_host_sized.get(req.cls, 0), (req.sid, req.cls)
                req.was_deferred = False
                req.host_tokens = 0
                end = self.stage_transfer(w, secs(staging_secs(reload)))
                self.schedule(end, ("stage_in", req, w, dw["epoch"]))
                return
            dw["active"].append(req)

    def on_stage_in_done(self, req, w):
        dw = self.decode[w]
        dw["staging_in"] -= 1
        dw["io_inflight"] -= 1
        dw["active"].append(req)
        self.try_admit_decode(w)
        self.maybe_step(w)

    def on_stage_out_done(self, w):
        self.decode[w]["io_inflight"] -= 1
        self.try_admit_decode(w)
        self.maybe_step(w)

    def maybe_step(self, w):
        dw = self.decode[w]
        if dw["stepping"] or dw["io_inflight"] > 0 or not dw["active"] or not dw["alive"]:
            return
        kv_total = 0
        for r in dw["active"]:
            kv_total += r.ctx_len + r.generated
        cost = decode_step_secs(len(dw["active"]), kv_total)
        f = slow_factor(dw["slow"], self.now)
        if f is not None:
            # Straggler GPU (decode_pool.rs::maybe_step): float cost
            # inflated before rounding.
            cost *= f
        if dw["assist"] is not None and self.now >= dw["assist"][0]:
            # Repartition-plane assist: the lent flex GPU halves step cost
            # once its KV migration has landed.
            cost *= dw["assist"][1]
        dur_us = secs(cost)
        dw["busy_micros"] += dur_us
        dw["stepping"] = True
        self.schedule_in(dur_us, ("step_done", w, dw["epoch"]))

    def on_decode_step_done(self, w):
        dw = self.decode[w]
        dw["stepping"] = False
        now = self.now
        finished = []
        i = 0
        while i < len(dw["active"]):
            r = dw["active"][i]
            r.generated += 1
            if not r.ttft_recorded:
                r.ttft_recorded = True
                t = to_secs(now - r.issued_at)
                self.ttft.record(t)
                record_pos(self.ttft_pos, r.call_idx, t)
                record_pos(self.ttft_depth, r.depth, t)
                # metrics.recent_ttfts (sim/mod.rs): buffered during the
                # step and drained to the slo-shed plane right after it —
                # the plane is only read at arrival events, so feeding it
                # inline here is observationally identical.
                self.plane_record_ttft(t)
            if r.generated >= r.out_tokens:
                done = swap_remove(dw["active"], i)
                dw["resident"] -= done.footprint()
                if self.cfg.get("decode_reuse") and not done.is_sink:
                    # Retain the finished request's KV on the worker
                    # (residency.rs::retain), tagged with its context's
                    # segment signature, instead of freeing it.
                    dw["res_clock"] += 1
                    assert done.sid not in dw["residency"], "retain without consume"
                    dw["residency"][done.sid] = {
                        "tokens": done.footprint(),
                        "base": done.base,
                        "sig": done.sig + [(done.call_idx, done.out_tokens)],
                        "cls": done.cls,
                        "last_use": dw["res_clock"],
                        "on_host": False,
                        "pinned": False,
                        "pinned_reuse": 0,
                        "relay_pins": 0,
                    }
                    dw["retained_gpu"] += done.footprint()
                    dw["peak_retained"] = max(dw["peak_retained"], dw["retained_gpu"])
                finished.append(done)
            else:
                i += 1
        n_done = len(finished)
        for req in finished:
            # ThroughputMeter.record
            self.m["generated_tokens"] += req.out_tokens
            at = to_secs(now)
            if self.tput_first is None:
                self.tput_first = at
            self.tput_last = at
            self.m["requests_completed"] += 1
            self.request_latency.record(to_secs(now - req.issued_at))
            self.on_call_complete(req)
        if n_done > 0:
            self.try_admit_decode(w)
        self.maybe_step(w)

    def on_call_complete(self, req):
        sid = req.sid
        node = req.call_idx
        st = self.sessions[sid]
        st["inflight"] -= 1
        st["remaining"] -= 1
        if self.open_crashes:
            # A crash is "recovered" once every call it tore down has
            # finally completed (sim/mod.rs::on_call_complete).
            now = self.now
            i = 0
            while i < len(self.open_crashes):
                oc = self.open_crashes[i]
                if (sid, node) in oc["torn"]:
                    oc["torn"].discard((sid, node))
                    if not oc["torn"]:
                        self.open_crashes.pop(i)
                        self.recovery_times.append(to_secs(now - oc["at"]))
                        continue
                i += 1
        # Unblock children; every node whose last parent this was issues
        # now as ONE batch, ascending node order, so same-class siblings
        # unblocked together can CoW-fork (sim/mod.rs::on_call_complete).
        ready = []
        for c in self.meta[sid][node]["children"]:
            st["pending"][c] -= 1
            if st["pending"][c] == 0:
                ready.append(c)
        if ready:
            self.issue_batch(sid, ready)
        if st["remaining"] == 0:
            self.session_latency.record(to_secs(self.now - st["arrival"]))
            self.m["sessions_completed"] += 1
            self.last_completion = self.now
            if self.cfg.get("decode_reuse"):
                # The session will never call again: free whatever KV the
                # decode tier still retains for it (GPU and host).
                for dw in self.decode:
                    e = dw["residency"].pop(sid, None)
                    if e is not None and not e["on_host"]:
                        dw["retained_gpu"] -= e["tokens"]
            self.admitted -= 1
            if self.admission_queue:
                self.admit(self.admission_queue.popleft())

    # -- failure injection + control plane --------------------------------

    def teardown_req(self, req, dw_idx):
        # sim/mod.rs::teardown_req — the request's decode worker crashed
        # out from under it: release PR 9's references, open a balanced
        # demand/lost pair (plus the sized-but-never-charged host reload
        # residue), and book the call for re-issue.
        if req.relay_src is not None:
            e = self.decode[req.relay_src]["residency"].get(req.sid)
            if e is not None:
                e["relay_pins"] = max(e["relay_pins"] - 1, 0)
            req.relay_src = None
        if req.fork_gid is not None:
            self.fork_drop_ref(req.fork_gid)
            req.fork_gid = None
        ctx = req.ctx_len
        uncharged = req.host_tokens
        cls = req.cls
        self.audit_demand[cls] = self.audit_demand.get(cls, 0) + ctx
        self.faultm["lost_tokens"] += ctx + uncharged
        self.bump_lost(cls, ctx + uncharged)
        self.faultm["wasted_generated_tokens"] += req.generated
        if uncharged > 0:
            # The reload was sized at handoff but will never be charged:
            # it moves to the lost channel instead.
            self.audit_host_sized[cls] -= uncharged
        for oc in reversed(self.open_crashes):
            if oc["tier"] == "d" and oc["target"] == dw_idx:
                oc["torn"].add((req.sid, req.call_idx))
                break
        if self.decode[dw_idx]["alive"]:
            # Stale event landed after the worker already recovered:
            # re-issue immediately.
            self.reissue_call(req.sid, req.call_idx)
        else:
            self.reissue[dw_idx].add((req.sid, req.call_idx))

    def prefill_crash(self, w):
        # prefill_pool.rs::crash — busy unit's job first, then the queue;
        # the radix cache is wiped wholesale (wiped tokens count as
        # evicted, the LRU clock restarts, capacity survives).
        pw = self.prefill[w]
        pw["alive"] = False
        jobs = []
        if pw["busy"] is not None:
            job, _path, _matched = pw["busy"]
            pw["busy"] = None
            jobs.append(job)
        jobs.extend(pw["queue"])
        pw["queue"].clear()
        old = pw["radix"]
        fresh = RadixCache(old.capacity)
        fresh.evicted_tokens = old.evicted_tokens + old.resident
        pw["radix"] = fresh
        return jobs

    def on_fault(self, idx):
        f = self.faults[idx]
        now = self.now
        if f["tier"] == "p":
            w = f["idx"]
            self.prefill_epoch[w] += 1
            jobs = self.prefill_crash(w)
            torn = set((j["sid"], j["call_idx"]) for j in jobs)
            self.open_crashes.append(
                {"idx": idx, "at": now, "tier": "p", "target": w, "torn": torn})
            # Queued and in-flight prefill work re-routes to the survivors
            # immediately: nothing was handed off yet, so no KV is lost.
            for job in jobs:
                w2 = self.route_alive(job)
                self.prefill[w2]["queue"].append(job)
                self.try_start_prefill(w2)
        else:
            w = f["idx"]
            # The record is pushed before the teardowns so teardown_req's
            # reverse scan finds this crash (sim/mod.rs::on_fault).
            self.open_crashes.append(
                {"idx": idx, "at": now, "tier": "d", "target": w, "torn": set()})
            dw = self.decode[w]
            dw["alive"] = False
            dw["epoch"] += 1
            torn_reqs = list(dw["active"]) + list(dw["pending"])
            dw["active"] = []
            dw["pending"].clear()
            dw["staging_in"] = 0
            dw["stepping"] = False
            dw["io_inflight"] = 0
            dw["resident"] = 0
            # residency.rs::crash_clear — sessions + GPU-retained count
            # only; the ledger clock and peak figures survive the crash.
            dw["residency"].clear()
            dw["retained_gpu"] = 0
            for req in torn_reqs:
                self.teardown_req(req, w)
        self.schedule_in(secs(self.cfg.get("fault_recovery_s", 10.0)), ("recover", idx))

    def on_recover(self, idx):
        f = self.faults[idx]
        if f["tier"] == "p":
            w = f["idx"]
            if not self.prefill[w]["alive"]:
                self.prefill[w]["alive"] = True
                self.try_start_prefill(w)
        else:
            w = f["idx"]
            self.decode[w]["alive"] = True
            # Re-issue every call the crash tore, ascending (sid, node)
            # (the rust side drains a BTreeSet).
            calls = sorted(self.reissue[w])
            self.reissue[w] = set()
            for (sid, node) in calls:
                self.reissue_call(sid, node)
        # A crash that tore nothing down recovers the moment its worker
        # does (sim/mod.rs::on_recover).
        for i, oc in enumerate(self.open_crashes):
            if oc["idx"] == idx and not oc["torn"]:
                self.open_crashes.pop(i)
                self.recovery_times.append(to_secs(self.now - oc["at"]))
                break

    def on_plane_tick(self):
        # sim/mod.rs::on_plane_tick + proxy.rs::RepartitionPlane::tick —
        # backlogs are read over alive workers only; an action needs
        # REPARTITION_STREAK consecutive wanting ticks.
        prefill_backlog = sum(
            len(pw["queue"]) + (1 if pw["busy"] is not None else 0)
            for pw in self.prefill if pw["alive"]
        )
        decode_backlog = sum(
            len(dw["pending"]) for dw in self.decode if dw["alive"]
        )
        if self.flex_lent:
            want = prefill_backlog > 2 * decode_backlog + 4
        else:
            want = decode_backlog > 2 * prefill_backlog + 4
        act = None
        if want:
            self.plane_streak += 1
            if self.plane_streak >= REPARTITION_STREAK:
                self.plane_streak = 0
                act = "reclaim" if self.flex_lent else "lend"
        else:
            self.plane_streak = 0
        if act == "lend":
            self.lend_flex()
        elif act == "reclaim":
            self.reclaim_flex()
        total = len(self.trace)
        if self.m["sessions_completed"] + self.faultm["shed_requests"] < total:
            self.schedule_in(secs(1.0), ("plane_tick",))

    def occupy(self, w, dur):
        # interconnect.rs::occupy — link time without payload bytes (and
        # without degradation: a KV migration is not a handoff).
        start = max(self.now, self.link_free[w]) if self.cfg.get("link_contended") else self.now
        end = start + dur
        self.link_free[w] = max(self.link_free[w], end)
        return end

    def lend_flex(self):
        # sim/mod.rs::lend_flex — drain the flex prefill GPU like a crash
        # (nothing is lost: jobs re-route), then assist the deepest-
        # backlog decode worker once a KV migration occupies its handoff
        # link.
        flex = len(self.prefill) - 1
        if len(self.prefill) < 2 or not self.prefill[flex]["alive"]:
            return
        self.faultm["repartition_events"] += 1
        self.flex_lent = True
        self.prefill_epoch[flex] += 1
        jobs = self.prefill_crash(flex)
        for job in jobs:
            w2 = self.route_alive(job)
            self.prefill[w2]["queue"].append(job)
            self.try_start_prefill(w2)
        target = 0
        best = len(self.decode[0]["pending"])
        for d in range(1, len(self.decode)):
            b = len(self.decode[d]["pending"])
            if b > best:
                best = b
                target = d
        dur = secs(handoff_secs(
            self.decode[target]["resident"], self.cfg.get("handoff_bps", HANDOFF_BPS)))
        at = self.occupy(target, dur)
        self.decode[target]["assist"] = (at, ASSIST_FACTOR)
        self.flex_target = target

    def reclaim_flex(self):
        # sim/mod.rs::reclaim_flex — undo the assist, pay the migration
        # back, revive the flex prefill GPU when the link frees.
        if not self.flex_lent:
            return
        flex = len(self.prefill) - 1
        self.faultm["repartition_events"] += 1
        self.flex_lent = False
        t = self.flex_target
        self.flex_target = None
        if t is not None:
            self.decode[t]["assist"] = None
            dur = secs(handoff_secs(
                self.decode[t]["resident"], self.cfg.get("handoff_bps", HANDOFF_BPS)))
            at = self.occupy(t, dur)
            self.schedule(at, ("flex_revive", flex))
        elif not self.prefill[flex]["alive"]:
            self.prefill[flex]["alive"] = True
            self.try_start_prefill(flex)

    # -- results ----------------------------------------------------------

    def finish(self):
        # Every fork group must have been fully dereferenced by handoff
        # completions (fork.rs::drained, asserted in sim finish()).
        assert not self.fork_groups and not self.fork_pending and self.fork_used == 0, \
            "fork registry not drained at finish"
        evicted = 0
        prefill_busy = 0
        for w in self.prefill:
            evicted += w["radix"].evicted_tokens
            prefill_busy += w["busy_micros"]
        decode_busy = 0
        peak_decode_resident = 0
        peak_retained = 0
        for d in self.decode:
            decode_busy += d["busy_micros"]
            peak_decode_resident = max(peak_decode_resident, d["peak_resident"])
            peak_retained = max(peak_retained, d["peak_retained"])
        makespan = to_secs(max(self.last_completion - min(self.first_arrival, self.last_completion), 0))
        span = max(makespan, 1e-9)
        throughput = float(self.m["generated_tokens"]) / span

        # Field evaluation order mirrors SimResult construction in finish():
        # session_latency p50/p95 sort before its mean; ttft mean runs on
        # insertion order before its p95 sorts.
        p50 = self.session_latency.quantile(0.50)
        p95 = self.session_latency.quantile(0.95)
        mean_lat = self.session_latency.mean()
        ttft_mean = self.ttft.mean()
        ttft_p95 = self.ttft.quantile(0.95)
        qd_mean = self.queue_delay.mean()
        qd_p95 = self.queue_delay.quantile(0.95)
        # Extended metrics, evaluated in SimResult construction order
        # (means run on insertion order before their p95 sorts).
        dqd_mean = self.decode_qd.mean()
        dqd_p95 = self.decode_qd.quantile(0.95)
        hw_mean = self.handoff_wait.mean()

        def imbalance(busy):
            # sim/mod.rs::imbalance — busy-time skew, max/mean per pool.
            total = sum(busy)
            if total == 0 or not busy:
                return 0.0
            return max(busy) / (total / len(busy))

        counters = dict(self.m)
        counters["evicted_tokens"] = evicted
        counters["peak_decode_resident_tokens"] = peak_decode_resident
        counters["peak_retained_kv_tokens"] = peak_retained
        floats = {
            "p50_session_latency": p50,
            "p95_session_latency": p95,
            "mean_session_latency": mean_lat,
            "ttft_mean": ttft_mean,
            "ttft_p95": ttft_p95,
            "throughput_tok_s": throughput,
            "makespan_s": makespan,
            "prefill_util": (to_secs(prefill_busy) / (makespan * len(self.prefill))) if makespan > 0.0 else 0.0,
            "decode_util": (to_secs(decode_busy) / (makespan * len(self.decode))) if makespan > 0.0 else 0.0,
            "prefill_queue_delay_mean": qd_mean,
            "prefill_queue_delay_p95": qd_p95,
        }
        extra = {
            "decode_queue_delay_mean": dqd_mean,
            "decode_queue_delay_p95": dqd_p95,
            "handoff_link_wait_mean": hw_mean,
            "prefill_util_imbalance": imbalance([w["busy_micros"] for w in self.prefill]),
            "ttft_pos0_mean": self.ttft_pos[0].mean() if self.ttft_pos else float("nan"),
            "ttft_pos_last_mean": self.ttft_pos[-1].mean() if self.ttft_pos else float("nan"),
        }
        # DAG-only floats (golden_fanout.json scenarios; kept out of
        # `extra` so the pre-DAG fixtures stay byte-identical).
        dag = {
            "ttft_depth0_mean": self.ttft_depth[0].mean() if self.ttft_depth else float("nan"),
            "ttft_depth_last_mean": self.ttft_depth[-1].mean() if self.ttft_depth else float("nan"),
        }
        # Failure-injection summary (sim/mod.rs::finish) — kept out of the
        # returned counters/floats so the six pre-fault fixtures' schemas
        # (and bytes) stay untouched; golden_faults.json reads this.
        if self.recovery_times:
            recovery_mean = sum(self.recovery_times) / float(len(self.recovery_times))
        else:
            recovery_mean = 0.0
        useful = max(self.m["generated_tokens"] - self.faultm["wasted_generated_tokens"], 0)
        goodput = (float(useful) / span) if makespan > 0.0 else 0.0
        self.fault_counters = dict(self.faultm)
        self.fault_counters["recovery_events"] = len(self.recovery_times)
        self.fault_floats = {
            "recovery_mean_s": recovery_mean,
            "goodput_tok_s": goodput,
        }
        return counters, floats, extra, dag


# ---------------------------------------------------------------------------
# fixture emission
# ---------------------------------------------------------------------------

GOLDEN_RATE = 2.0
GOLDEN_DURATION = 60.0
GOLDEN_TRACE_SEED = 42

# Residency counters only the reuse/fanout fixtures pin; stripped from the
# fifo/routes fixtures so their schema (and bytes, absent behaviour
# changes) stays stable across the decode-reuse feature landing.
REUSE_COUNTER_KEYS = (
    "handoffs_delta",
    "handoff_tokens_delta",
    "decode_reuse_tokens",
    "retained_evictions",
    "retained_evicted_tokens",
    "host_parks",
    "host_reloads",
    "host_reload_tokens",
    "peak_retained_kv_tokens",
)


def strip_reuse(counters):
    out = dict(counters)
    for k in REUSE_COUNTER_KEYS:
        assert out.pop(k) == 0, (k, "nonzero reuse counter in a reuse-off scenario")
    return out


def strip_chain(counters):
    """Chain fixtures predate the DAG axis: a chain session never overlaps
    its own calls, and the counter stays out of those fixtures' bytes."""
    out = dict(counters)
    peak = out.pop("peak_session_inflight")
    assert peak == 1, ("chain scenario overlapped its own calls", peak)
    return out


def context_demand(sim):
    """Sum of every call's input-context length — the conservation target
    for delta accounting: shipped + gpu-reused + host-reloaded must equal
    this exactly."""
    return sum(m["ctx"] for metas in sim.meta for m in metas)


def context_demand_by_class(sim):
    """Per-class split of `context_demand` — the per-class conservation
    target: within each compatibility class, shipped + gpu-reused +
    host-reloaded must equal that class's context demand (no class ever
    balances its books with another's KV)."""
    d = []
    for sid, metas in enumerate(sim.meta):
        for i, m in enumerate(metas):
            c = sim.trace[sid]["calls"][i]["cls"]
            while len(d) <= c:
                d.append(0)
            d[c] += m["ctx"]
    return d


def padded(lst, n):
    return lst + [0] * (n - len(lst))


def pad_get(lst, i):
    """Per-class counter slot, 0 when the class has no slot yet."""
    return lst[i] if i < len(lst) else 0


def trace_header(spec, trace, total_calls):
    return {
        "workload": spec["name"],
        "rate": GOLDEN_RATE,
        "duration_s": GOLDEN_DURATION,
        "seed": GOLDEN_TRACE_SEED,
        "sessions": len(trace),
        "calls": total_calls,
    }


def write_fixture(filename, fixture):
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), filename)
    with open(out, "w") as f:
        json.dump(fixture, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")


def main():
    trace = generate_trace(REACT, GOLDEN_RATE, GOLDEN_DURATION, GOLDEN_TRACE_SEED)
    total_calls = sum(len(s["calls"]) for s in trace)

    # -- golden_fifo.json: the pre-decomposition default (unchanged) --------
    scenarios = []
    for system in ("prefillshare", "baseline"):
        counters, floats, _extra, _dag = Simulator(cluster_config(system), trace).run()
        assert counters["sessions_completed"] == len(trace), (system, counters)
        assert counters["requests_completed"] == total_calls
        assert counters["prefix_miss_tokens"] == counters["prefill_computed_tokens"]
        scenarios.append(
            {
                "name": f"{system}-fifo",
                "system": system,
                "counters": strip_chain(strip_reuse(counters)),
                "floats": floats,
            }
        )

    fixture = {
        "description": "Golden FIFO metrics for ClusterConfig::paper_default over "
        "generate_trace(react, 2.0, 60.0, 42); generated by gen_golden.py "
        "(bit-faithful port of the rust simulator). Counters compare exactly, "
        "floats to 1e-6 relative tolerance.",
        "trace": trace_header(REACT, trace, total_calls),
        "scenarios": scenarios,
    }
    write_fixture("golden_fifo.json", fixture)
    for s in scenarios:
        c, fl = s["counters"], s["floats"]
        print(
            f"  {s['name']}: {c['sessions_completed']} sessions, "
            f"{c['prefill_computed_tokens']} prefill tokens, hit {c['prefix_hit_tokens']}, "
            f"p95 {fl['p95_session_latency']:.3f}s, tput {fl['throughput_tok_s']:.0f} tok/s"
        )

    # -- golden_routes.json: routing subsystem + contended interconnect ----
    # (routing, link_contended, handoff_gbps) per scenario; the rust test
    # rebuilds ClusterConfig from these fields.
    route_scenarios = []
    for name, routing, contended, gbps, decode_kv in (
        ("prefillshare-rr", "rr", False, 64.0, None),
        ("prefillshare-rr-link8", "rr", True, 8.0, None),
        ("prefillshare-prefix-link8", "prefix", True, 8.0, None),
        ("prefillshare-cache", "cache", False, 64.0, None),
        # Decode-KV pressure + contended links: exercises the staging links
        # (App. B.2 regime), so the contended-staging path is pinned too.
        ("prefillshare-rr-link8-staged", "rr", True, 8.0, 4000),
    ):
        cfg = cluster_config(
            "prefillshare", routing=routing, link_contended=contended, handoff_bps=gbps * 1e9
        )
        if decode_kv is not None:
            cfg["decode_kv_tokens"] = decode_kv
        counters, floats, extra, _dag = Simulator(cfg, trace).run()
        assert counters["sessions_completed"] == len(trace), (name, counters)
        assert counters["requests_completed"] == total_calls, name
        if decode_kv is not None:
            assert counters["staging_events"] > 0, (name, "expected staging pressure")
        route_scenarios.append(
            {
                "name": name,
                "routing": routing,
                "link_contended": contended,
                "link_gbps": gbps,
                "decode_kv_tokens": decode_kv,
                "counters": strip_chain(strip_reuse(counters)),
                "floats": {**floats, **extra},
            }
        )
        print(
            f"  {name}: hit {counters['prefix_hit_tokens']}, "
            f"p95 {floats['p95_session_latency']:.3f}s, "
            f"link wait mean {extra['handoff_link_wait_mean'] * 1e3:.3f}ms, "
            f"imb {extra['prefill_util_imbalance']:.3f}"
        )

    routes_fixture = {
        "description": "Golden routing/interconnect metrics over the same trace: "
        "round-robin and cache-aware routing, uncontended vs contended per-link "
        "FIFO handoff (8 GB/s), FIFO scheduling throughout; generated by "
        "gen_golden.py (bit-faithful port of the rust simulator). Counters "
        "compare exactly, floats to 1e-6 relative tolerance.",
        "trace": trace_header(REACT, trace, total_calls),
        "scenarios": route_scenarios,
    }
    write_fixture("golden_routes.json", routes_fixture)

    # -- golden_reuse.json: decode-side session KV residency ---------------
    # Same trace; each scenario also records the reuse-off handoff traffic
    # of the identical config, pinning the delta-handoff savings the rust
    # side re-verifies (>= 40% fewer shipped tokens).
    reuse_scenarios = []
    for name, routing, contended, gbps, decode_kv, expect_delta in (
        # Default capacity: retention + delta handoff (the retained pool
        # peaks at ~55k of the ~85k cap here, so no evictions fire).
        ("prefillshare-reuse", "prefix", False, 64.0, None, True),
        # Contended 8 GB/s ingress: delta handoffs shrink link waits too.
        ("prefillshare-reuse-rr-link8", "rr", True, 8.0, None, True),
        # 4 GB/s handoff + tight decode KV: eviction prices host-parking
        # below a future re-handoff, exercising park + reload staging.
        ("prefillshare-reuse-link4-tight", "rr", True, 4.0, 4000, True),
        # Tight decode KV on the default 64 GB/s link: eviction prices
        # *discard* cheaper, so retained KV is dropped before sessions
        # return (no delta handoffs survive) — pins the discard branch's
        # accounting, which no other scenario reaches.
        ("prefillshare-reuse-tight-discard", "prefix", False, 64.0, 4000, False),
    ):
        def build(decode_reuse):
            cfg = cluster_config(
                "prefillshare",
                routing=routing,
                link_contended=contended,
                handoff_bps=gbps * 1e9,
                decode_reuse=decode_reuse,
            )
            if decode_kv is not None:
                cfg["decode_kv_tokens"] = decode_kv
            return cfg

        counters, floats, extra, _dag = Simulator(build(True), trace).run()
        off_counters, _of, _oe, _od = Simulator(build(False), trace).run()
        assert counters["sessions_completed"] == len(trace), (name, counters)
        assert counters["requests_completed"] == total_calls, name
        assert off_counters["sessions_completed"] == len(trace), (name, "reuse-off lost sessions")
        assert counters["handoff_tokens"] <= off_counters["handoff_tokens"], name
        saved = 1.0 - counters["handoff_tokens"] / off_counters["handoff_tokens"]
        if expect_delta:
            assert counters["handoffs_delta"] > 0, (name, "no delta handoffs")
            assert saved >= 0.4, (name, "delta handoff saved only", saved)
        if name.endswith("link4-tight"):
            assert counters["host_parks"] > 0, (name, "expected host-parked evictions")
            assert counters["host_reloads"] > 0, (name, "expected host reloads")
        if name.endswith("tight-discard"):
            assert counters["retained_evictions"] > 0, (name, "expected discard evictions")
            assert counters["host_parks"] == 0, (name, "64 GB/s link must price discard cheaper")
        reuse_scenarios.append(
            {
                "name": name,
                "routing": routing,
                "link_contended": contended,
                "link_gbps": gbps,
                "decode_kv_tokens": decode_kv,
                "expect_delta": expect_delta,
                "handoff_tokens_no_reuse": off_counters["handoff_tokens"],
                "counters": strip_chain(counters),
                "floats": {**floats, **extra},
            }
        )
        print(
            f"  {name}: shipped {counters['handoff_tokens']} vs {off_counters['handoff_tokens']} "
            f"tokens ({100.0 * saved:.1f}% saved), reuse {counters['decode_reuse_tokens']}, "
            f"evictions {counters['retained_evictions']} "
            f"(host parks {counters['host_parks']}), peak retained {counters['peak_retained_kv_tokens']}"
        )

    reuse_fixture = {
        "description": "Golden decode-reuse metrics over the same trace: session "
        "KV residency with delta handoff, LRU retained-KV eviction "
        "(discard vs host-park by cost), and host reloads; generated by "
        "gen_golden.py (bit-faithful port of the rust simulator). Counters "
        "compare exactly, floats to 1e-6 relative tolerance; "
        "handoff_tokens_no_reuse pins the same config with reuse off.",
        "trace": trace_header(REACT, trace, total_calls),
        "scenarios": reuse_scenarios,
    }
    write_fixture("golden_reuse.json", reuse_fixture)

    # -- golden_fanout.json: DAG workloads with parallel fan-out -----------
    # Fresh traces per workload (same rate/duration/seed); the rust test
    # rebuilds each scenario from (workload, routing, link, decode_reuse).
    dag_traces = {
        wl: generate_trace(WORKLOADS[wl], GOLDEN_RATE, GOLDEN_DURATION, GOLDEN_TRACE_SEED)
        for wl in ("fanout", "mixed")
    }
    fanout_scenarios = []
    for name, wl, routing, contended, gbps, decode_reuse in (
        # The headline regime: prefix-aware routing, sibling specialists
        # radix-hitting the planner's context concurrently.
        ("prefillshare-fanout", "fanout", "prefix", False, 64.0, False),
        # Concurrent sibling delta handoffs: one session pins residency
        # entries on several decode workers at once.
        ("prefillshare-fanout-reuse", "fanout", "prefix", False, 64.0, True),
        # Sibling handoffs serialized on a contended 8 GB/s ingress under
        # locality-destroying routing.
        ("prefillshare-fanout-rr-link8", "fanout", "rr", True, 8.0, False),
        # Blended chain + tree sessions with residency on: pins the
        # variant draw and chain/DAG coexistence on one ledger.
        ("prefillshare-mixed-reuse", "mixed", "prefix", False, 64.0, True),
    ):
        dag_trace = dag_traces[wl]
        dag_calls = sum(len(s["calls"]) for s in dag_trace)

        def build(reuse):
            return cluster_config(
                "prefillshare",
                routing=routing,
                link_contended=contended,
                handoff_bps=gbps * 1e9,
                decode_reuse=reuse,
                spec=WORKLOADS[wl],
            )

        sim = Simulator(build(decode_reuse), dag_trace)
        counters, floats, extra, dag = sim.run()
        assert counters["sessions_completed"] == len(dag_trace), (name, counters)
        assert counters["requests_completed"] == dag_calls, name
        min_overlap = 3 if wl == "fanout" else 2
        assert counters["peak_session_inflight"] >= min_overlap, (
            name, "sibling calls never overlapped", counters["peak_session_inflight"])
        scenario = {
            "name": name,
            "workload": wl,
            "routing": routing,
            "link_contended": contended,
            "link_gbps": gbps,
            "decode_reuse": decode_reuse,
            "counters": counters if decode_reuse else strip_reuse(counters),
            "floats": {**floats, **extra, **dag},
        }
        if decode_reuse:
            off_counters, _of, _oe, _od = Simulator(build(False), dag_trace).run()
            assert off_counters["sessions_completed"] == len(dag_trace), name
            # Conservation identity under concurrent sibling pinning:
            # every call's context demand is shipped, reused or reloaded.
            demand = context_demand(sim)
            assert (
                counters["handoff_tokens"]
                + counters["decode_reuse_tokens"]
                + counters["host_reload_tokens"]
                == demand
            ), (name, "delta accounting lost tokens")
            assert counters["handoff_tokens"] <= off_counters["handoff_tokens"], name
            assert counters["handoffs_delta"] > 0, (name, "no delta handoffs")
            scenario["handoff_tokens_no_reuse"] = off_counters["handoff_tokens"]
        fanout_scenarios.append(scenario)
        print(
            f"  {name}: {counters['sessions_completed']} sessions, peak inflight "
            f"{counters['peak_session_inflight']}, hit {counters['prefix_hit_tokens']}, "
            f"shipped {counters['handoff_tokens']}, p95 {floats['p95_session_latency']:.3f}s"
        )

    fanout_fixture = {
        "description": "Golden DAG-workload metrics: fanout (planner -> 3 parallel "
        "specialists -> joiner) and mixed (50/50 chain/fanout blend) sessions "
        "with parallel fan-out — multiple in-flight calls per session — under "
        "prefix-aware and round-robin routing, contended links, and decode-side "
        "residency with signature-LCP delta handoff; generated by gen_golden.py "
        "(bit-faithful port of the rust simulator). Counters compare exactly, "
        "floats to 1e-6 relative tolerance; reuse scenarios also pin the "
        "reuse-off handoff traffic of the identical config.",
        "traces": {
            wl: trace_header(WORKLOADS[wl], tr, sum(len(s["calls"]) for s in tr))
            for wl, tr in dag_traces.items()
        },
        "scenarios": fanout_scenarios,
    }
    write_fixture("golden_fanout.json", fanout_fixture)

    # -- golden_prefillshare.json: prefill-module compatibility classes ----
    # Fresh traces per (workload, class map); shared (one class spanning
    # every model) vs per-model private classes.  Pins the per-class
    # counter splits, per-class byte conservation under decode reuse, and
    # the headline direction: private prefill forfeits cross-model reuse.
    PRIVATE = list(range(4))  # one class per model (n_models = 4)
    ps_scenarios = []
    shared_hits = {}
    for name, wl, classes, decode_reuse in (
        ("prefillshare-shared-fanout", "fanout", [], False),
        ("prefillshare-private-fanout", "fanout", PRIVATE, False),
        ("prefillshare-private-debate", "debate", PRIVATE, False),
        ("prefillshare-private-fanout-reuse", "fanout", PRIVATE, True),
    ):
        spec = with_classes(WORKLOADS[wl], classes)
        tr = generate_trace(spec, GOLDEN_RATE, GOLDEN_DURATION, GOLDEN_TRACE_SEED)
        n_calls = sum(len(s["calls"]) for s in tr)
        sim = Simulator(cluster_config("prefillshare", decode_reuse=decode_reuse, spec=spec), tr)
        counters, floats, extra, _dag = sim.run()
        assert counters["sessions_completed"] == len(tr), (name, counters)
        assert counters["requests_completed"] == n_calls, name
        by_class = {f"{k}_by_class": list(v) for k, v in sim.by_class.items()}
        # Per-class sums must equal the scalar counters at every point.
        for k, v in sim.by_class.items():
            assert sum(v) == counters[k], (name, k, v, counters[k])
        if not classes:
            # Single shared class: exactly one populated slot — and the
            # run must be identical to the pre-class fanout golden
            # (same trace, config and counters as prefillshare-fanout).
            assert all(len(v) <= 1 for v in sim.by_class.values()), (name, sim.by_class)
            shared_hits[wl] = counters["prefix_hit_tokens"]
        else:
            assert len(sim.by_class["prefix_miss_tokens"]) == len(set(classes)), name
        if decode_reuse:
            demand = context_demand_by_class(sim)
            n = len(demand)
            shipped = padded(sim.by_class["handoff_tokens"], n)
            reused = padded(sim.by_class["decode_reuse_tokens"], n)
            reloaded = padded(sim.by_class["host_reload_tokens"], n)
            for c in range(n):
                assert shipped[c] + reused[c] + reloaded[c] == demand[c], (
                    name, "class", c, "lost tokens")
            # sim/mod.rs::audit_finish: by end of run every host-sized token
            # has been reloaded — the in-flight gap closes exactly.
            for c, s in getattr(sim, "audit_host_sized", {}).items():
                assert pad_get(reloaded, c) == s, (name, "class", c, "reload vs sized")
        ps_scenarios.append(
            {
                "name": name,
                "workload": wl,
                "prefill_classes": list(classes),
                "decode_reuse": decode_reuse,
                "counters": {**(counters if decode_reuse else strip_reuse(counters)), **by_class},
                "floats": {**floats, **extra},
            }
        )
        print(
            f"  {name}: hit {counters['prefix_hit_tokens']}, "
            f"miss by class {sim.by_class['prefix_miss_tokens']}, "
            f"p95 {floats['p95_session_latency']:.3f}s"
        )
    # Headline direction: the private map must forfeit cross-model reuse.
    private_fanout = next(s for s in ps_scenarios if s["name"] == "prefillshare-private-fanout")
    assert private_fanout["counters"]["prefix_hit_tokens"] < shared_hits["fanout"], (
        "private classes must reuse strictly less than the shared module")

    ps_fixture = {
        "description": "Golden prefill-module compatibility-class metrics: shared "
        "(one class spanning every model) vs per-model private classes on the "
        "fanout/debate DAG workloads, with per-class counter splits and "
        "per-class byte conservation under decode-side residency; generated "
        "by gen_golden.py (bit-faithful port of the rust simulator). Counters "
        "compare exactly, floats to 1e-6 relative tolerance.",
        "traces": {
            wl: trace_header(WORKLOADS[wl], tr, sum(len(s["calls"]) for s in tr))
            for wl, tr in (
                ("fanout", generate_trace(FANOUT, GOLDEN_RATE, GOLDEN_DURATION, GOLDEN_TRACE_SEED)),
                ("debate", generate_trace(DEBATE, GOLDEN_RATE, GOLDEN_DURATION, GOLDEN_TRACE_SEED)),
            )
        },
        "scenarios": ps_scenarios,
    }
    write_fixture("golden_prefillshare.json", ps_fixture)

    # -- golden_forkrelay.json: CoW fork + decode-KV relay reuse ladder ----
    # Fresh fanout/debate traces at the forkrelay experiment's pinned
    # seeds (0, 1); each (workload, seed) runs the three reuse-ladder arms
    # above `off` — delta, delta+relay, delta+relay+fork — and pins the
    # fork/relay counters, their per-class splits, the five-channel
    # conservation identity, and the ladder's shipped-token direction.
    FORKRELAY_RATE = 2.0  # experiments.rs::FORKRELAY_RATE
    FORKRELAY_SEEDS = (0, 1)  # experiments.rs::FORKRELAY_SEEDS
    ARMS = (
        ("delta", {}),
        ("delta+relay", {"relay": True}),
        ("delta+relay+fork", {"relay": True, "fork": True}),
    )
    fr_scenarios = []
    fr_traces = {}
    for wl in ("fanout", "debate"):
        for seed in FORKRELAY_SEEDS:
            tr = generate_trace(WORKLOADS[wl], FORKRELAY_RATE, GOLDEN_DURATION, seed)
            n_calls = sum(len(s["calls"]) for s in tr)
            fr_traces[f"{wl}-{seed}"] = {
                "workload": wl,
                "rate": FORKRELAY_RATE,
                "duration_s": GOLDEN_DURATION,
                "seed": seed,
                "sessions": len(tr),
                "calls": n_calls,
            }
            shipped = {}
            for reuse, kw in ARMS:
                sim = Simulator(
                    cluster_config(
                        "prefillshare", decode_reuse=True, spec=WORKLOADS[wl], **kw
                    ),
                    tr,
                )
                counters, floats, extra, dag = sim.run()
                tag = (wl, seed, reuse)
                assert counters["sessions_completed"] == len(tr), (tag, counters)
                assert counters["requests_completed"] == n_calls, tag
                fr = dict(sim.forkrelay)
                # Five-channel conservation: every call's context demand is
                # shipped, gpu-reused, host-reloaded, forked or relayed.
                demand = context_demand(sim)
                assert (
                    counters["handoff_tokens"]
                    + counters["decode_reuse_tokens"]
                    + counters["host_reload_tokens"]
                    + fr["forked_tokens"]
                    + fr["relayed_tokens"]
                    == demand
                ), (tag, "five-channel accounting lost tokens")
                if reuse == "delta":
                    assert fr["forked_tokens"] == 0 and fr["relayed_tokens"] == 0, tag
                if reuse == "delta+relay":
                    assert fr["forked_tokens"] == 0, tag
                    assert fr["relayed_tokens"] > 0, (tag, "relay rung never relayed")
                if reuse == "delta+relay+fork":
                    assert fr["forked_tokens"] > 0, (tag, "fork rung never forked")
                shipped[reuse] = counters["handoff_tokens"]
                fr_by_class = {
                    f"{k}_by_class": list(v) for k, v in sim.forkrelay_by_class.items()
                }
                fr_scenarios.append(
                    {
                        "name": f"{wl}-s{seed}-{reuse}",
                        "workload": wl,
                        "seed": seed,
                        "reuse": reuse,
                        "counters": {**counters, **fr, **fr_by_class},
                        "floats": {**floats, **extra, **dag},
                    }
                )
                print(
                    f"  {wl}-s{seed}-{reuse}: shipped {counters['handoff_tokens']}, "
                    f"forked {fr['forked_tokens']}, relayed {fr['relayed_tokens']}, "
                    f"reused {counters['decode_reuse_tokens']}, "
                    f"p95 {floats['p95_session_latency']:.3f}s"
                )
            # Ladder direction: each rung never ships more than the one
            # below it; on fanout (the ISSUE's pinned acceptance regime)
            # the relay rung and the full ladder save strictly.
            assert shipped["delta+relay"] <= shipped["delta"], (wl, seed, shipped)
            assert shipped["delta+relay+fork"] <= shipped["delta+relay"], (wl, seed, shipped)
            if wl == "fanout":
                assert shipped["delta+relay"] < shipped["delta"], (wl, seed, shipped)
            assert shipped["delta+relay+fork"] < shipped["delta"], (wl, seed, shipped)

    fr_fixture = {
        "description": "Golden reuse-ladder metrics for CoW KV forking and "
        "decode-KV relay: fanout/debate traces at the forkrelay experiment's "
        "pinned seeds (0, 1), each run under --reuse delta, delta+relay and "
        "delta+relay+fork, pinning forked/relayed token counters (and their "
        "per-class splits) plus the five-channel conservation identity "
        "shipped + reused + reloaded + forked + relayed == context demand; "
        "generated by gen_golden.py (bit-faithful port of the rust "
        "simulator). Counters compare exactly, floats to 1e-6 relative "
        "tolerance.",
        "traces": fr_traces,
        "scenarios": fr_scenarios,
    }
    write_fixture("golden_forkrelay.json", fr_fixture)

    # -- golden_faults.json: failure injection + SLO control plane ---------
    # Pins the fault subsystem end to end: prefill/decode crashes (with
    # epoch-guarded teardown + re-issue), link degradation windows,
    # straggler GPUs, the slo-shed and repartition control planes, the
    # sixth conservation channel (`lost`), the recovery/goodput figures
    # and the `--faults random` schedule sampler.
    FAULTS_RECOVERY_S = 10.0
    FAULTS_OVERLOAD_RATE = 6.0    # experiments.rs::FAULTS_OVERLOAD_RATE
    FAULTS_SLO_TTFT_MS = 40.0     # experiments.rs::FAULTS_SLO_TTFT_MS
    FAULTS_REPARTITION_RATE = 4.0  # experiments.rs::FAULTS_REPARTITION_RATE
    FAULTS_SHORT_DURATION = 40.0

    def reuse_kwargs(label):
        return {
            "off": {},
            "delta": {"decode_reuse": True},
            "delta+relay": {"decode_reuse": True, "relay": True},
            "delta+relay+fork": {"decode_reuse": True, "relay": True, "fork": True},
        }[label]

    fault_scenarios = []
    fault_traces = {}

    def run_faults(name, wl, rate, duration, seed, reuse, faults,
                   control_plane="static", slo_ttft_ms=500.0,
                   max_decode_batch=None, link_contended=False):
        spec = WORKLOADS[wl]
        tkey = f"{wl}-r{rate}-d{duration}-s{seed}"
        if tkey not in fault_traces:
            tr = generate_trace(spec, rate, duration, seed)
            fault_traces[tkey] = {
                "workload": wl,
                "rate": rate,
                "duration_s": duration,
                "seed": seed,
                "sessions": len(tr),
                "calls": sum(len(s["calls"]) for s in tr),
                "_trace": tr,
            }
        tr = fault_traces[tkey]["_trace"]
        cfg = cluster_config(
            "prefillshare", spec=spec, link_contended=link_contended,
            faults=faults, fault_recovery_s=FAULTS_RECOVERY_S,
            control_plane=control_plane, slo_ttft_ms=slo_ttft_ms,
            **reuse_kwargs(reuse),
        )
        if max_decode_batch is not None:
            cfg["max_decode_batch"] = max_decode_batch
        sim = Simulator(cfg, tr)
        counters, floats, extra, dag = sim.run()
        fc = sim.fault_counters
        fr = sim.forkrelay
        # Six-channel conservation: every sized context token is shipped,
        # gpu-reused, host-reloaded, forked, relayed or lost — per class
        # and in total (demand is re-posted for every re-issued call, so
        # the target is the audit ledger, not the static trace demand).
        demand_by_class = sim.audit_demand
        demand = sum(demand_by_class.values())
        demand_list = []
        for c, v in sorted(demand_by_class.items()):
            while len(demand_list) <= c:
                demand_list.append(0)
            demand_list[c] = v
        covered = (
            counters["handoff_tokens"]
            + counters["decode_reuse_tokens"]
            + counters["host_reload_tokens"]
            + fr["forked_tokens"]
            + fr["relayed_tokens"]
            + fc["lost_tokens"]
        )
        assert covered == demand, (name, "six-channel accounting", covered, demand)
        for c, want in demand_by_class.items():
            got = (
                pad_get(sim.by_class["handoff_tokens"], c)
                + pad_get(sim.by_class["decode_reuse_tokens"], c)
                + pad_get(sim.by_class["host_reload_tokens"], c)
                + pad_get(sim.forkrelay_by_class["forked_tokens"], c)
                + pad_get(sim.forkrelay_by_class["relayed_tokens"], c)
                + pad_get(sim.lost_by_class, c)
            )
            assert got == want, (name, "class", c, "six-channel accounting")
        # Lost is a crash-only channel; shed is an slo-shed-only outcome.
        if not any(f["kind"] == "crash" for f in faults):
            assert fc["lost_tokens"] == 0, (name, fc)
            assert fc["recovery_events"] == 0, (name, fc)
        if control_plane != "slo-shed":
            assert fc["shed_requests"] == 0, (name, fc)
        if control_plane != "repartition":
            assert fc["repartition_events"] == 0, (name, fc)
        # Every non-shed session still completes: crashes tear calls down
        # but re-issue recovers each one.
        assert counters["sessions_completed"] == len(tr) - fc["shed_requests"], (
            name, counters["sessions_completed"], len(tr), fc)
        fault_scenarios.append(
            {
                "name": name,
                "workload": wl,
                "rate": rate,
                "duration_s": duration,
                "seed": seed,
                "reuse": reuse,
                "link_contended": link_contended,
                "control_plane": control_plane,
                "slo_ttft_ms": slo_ttft_ms,
                "fault_recovery_s": FAULTS_RECOVERY_S,
                "max_decode_batch": cfg["max_decode_batch"],
                "faults": [dict(f) for f in faults],
                "counters": {
                    **counters, **fr, **fc,
                    "lost_tokens_by_class": list(sim.lost_by_class),
                    "ctx_demand_tokens": demand,
                    "ctx_demand_tokens_by_class": demand_list,
                },
                "floats": {**floats, **extra, **dag, **sim.fault_floats},
            }
        )
        print(
            f"  {name}: lost {fc['lost_tokens']}, shed {fc['shed_requests']}, "
            f"recoveries {fc['recovery_events']} "
            f"(mean {sim.fault_floats['recovery_mean_s']:.2f}s), "
            f"goodput {sim.fault_floats['goodput_tok_s']:.0f} tok/s"
        )
        return fault_scenarios[-1]

    # Clean reference run for the degradation-direction asserts below.
    clean = run_faults("clean-baseline", "react", GOLDEN_RATE, GOLDEN_DURATION,
                       GOLDEN_TRACE_SEED, "off", [])
    crash_p = run_faults("crash-prefill", "react", GOLDEN_RATE, GOLDEN_DURATION,
                         GOLDEN_TRACE_SEED, "off",
                         [fault("crash", "p", 1, 10.0)])
    # A prefill crash loses nothing: queued work re-routes pre-handoff.
    assert crash_p["counters"]["lost_tokens"] == 0, crash_p["counters"]
    assert crash_p["counters"]["recovery_events"] >= 1, crash_p["counters"]

    crash_d = run_faults("crash-decode", "react", GOLDEN_RATE, GOLDEN_DURATION,
                         GOLDEN_TRACE_SEED, "delta",
                         [fault("crash", "d", 0, 15.0)])
    assert crash_d["counters"]["lost_tokens"] > 0, crash_d["counters"]
    assert crash_d["counters"]["recovery_events"] >= 1, crash_d["counters"]

    crash_fr = run_faults("crash-decode-forkrelay", "fanout", FORKRELAY_RATE,
                          GOLDEN_DURATION, 0, "delta+relay+fork",
                          [fault("crash", "d", 0, 15.0)])
    assert crash_fr["counters"]["lost_tokens"] > 0, crash_fr["counters"]
    assert crash_fr["counters"]["forked_tokens"] > 0, crash_fr["counters"]
    assert crash_fr["counters"]["relayed_tokens"] > 0, crash_fr["counters"]

    link_deg = run_faults("link-degrade", "react", GOLDEN_RATE, GOLDEN_DURATION,
                          GOLDEN_TRACE_SEED, "off",
                          [fault("link", "l", 0, 5.0, 40.0, 8.0)],
                          link_contended=True)
    link_clean = run_faults("link-clean", "react", GOLDEN_RATE, GOLDEN_DURATION,
                            GOLDEN_TRACE_SEED, "off", [], link_contended=True)
    assert (
        link_deg["floats"]["handoff_link_wait_mean"]
        > link_clean["floats"]["handoff_link_wait_mean"]
    ), "a degraded link must queue handoffs it would otherwise absorb"

    strag_p = run_faults("straggler-prefill", "react", GOLDEN_RATE, GOLDEN_DURATION,
                         GOLDEN_TRACE_SEED, "off",
                         [fault("straggler", "p", 0, 5.0, 40.0, 2.5)])
    strag_d = run_faults("straggler-decode", "react", GOLDEN_RATE, GOLDEN_DURATION,
                         GOLDEN_TRACE_SEED, "off",
                         [fault("straggler", "d", 1, 5.0, 40.0, 3.0)])
    for s in (strag_p, strag_d):
        assert s["floats"]["p95_session_latency"] > clean["floats"]["p95_session_latency"], (
            s["name"], "a straggler window must stretch tail latency")

    # SLO control plane under overload: the slo-shed plane trades shed
    # sessions for a strictly better served-TTFT tail (the `faults`
    # experiment's pinned acceptance direction).
    ov_static = run_faults("overload-static", "react", FAULTS_OVERLOAD_RATE,
                           FAULTS_SHORT_DURATION, GOLDEN_TRACE_SEED, "off", [],
                           control_plane="static", slo_ttft_ms=FAULTS_SLO_TTFT_MS)
    ov_shed = run_faults("overload-slo-shed", "react", FAULTS_OVERLOAD_RATE,
                         FAULTS_SHORT_DURATION, GOLDEN_TRACE_SEED, "off", [],
                         control_plane="slo-shed", slo_ttft_ms=FAULTS_SLO_TTFT_MS)
    assert ov_shed["counters"]["shed_requests"] > 0, ov_shed["counters"]
    assert (
        ov_shed["floats"]["ttft_p95"] < ov_static["floats"]["ttft_p95"]
    ), ("slo-shed must strictly improve p95 TTFT at the pinned overload point",
        ov_shed["floats"]["ttft_p95"], ov_static["floats"]["ttft_p95"])

    repart = run_faults("repartition", "react", FAULTS_REPARTITION_RATE,
                        FAULTS_SHORT_DURATION, GOLDEN_TRACE_SEED, "off", [],
                        control_plane="repartition", max_decode_batch=1)
    assert repart["counters"]["repartition_events"] >= 1, repart["counters"]

    # `--faults random`: the sampled schedule is a pure function of
    # (k, topology, duration, seed) — pin it field-for-field and run it.
    rnd = sample_random(3, 4, 4, GOLDEN_DURATION, 7)
    assert rnd == sample_random(3, 4, 4, GOLDEN_DURATION, 7), "sampler must be deterministic"
    run_faults("random-faults", "react", GOLDEN_RATE, GOLDEN_DURATION,
               GOLDEN_TRACE_SEED, "delta", rnd)

    for t in fault_traces.values():
        del t["_trace"]
    faults_fixture = {
        "description": "Golden failure-injection + SLO control-plane metrics: "
        "prefill/decode crashes (epoch-guarded teardown and re-issue), handoff-"
        "link degradation windows, straggler GPUs, the slo-shed and repartition "
        "control planes, the six-channel conservation identity shipped + reused "
        "+ reloaded + forked + relayed + lost == sized context demand, recovery "
        "time and goodput-under-failure, plus the `--faults random` schedule "
        "sampler; generated by gen_golden.py (bit-faithful port of the rust "
        "simulator). Counters compare exactly, floats to 1e-6 relative "
        "tolerance.",
        "traces": fault_traces,
        "random_schedule": {
            "k": 3,
            "n_prefill": 4,
            "n_decode": 4,
            "duration_s": GOLDEN_DURATION,
            "seed": 7,
            "faults": rnd,
        },
        "scenarios": fault_scenarios,
    }
    write_fixture("golden_faults.json", faults_fixture)


if __name__ == "__main__":
    main()

//! Property-based tests (in-tree `propcheck` style: seeded random input
//! generation over many cases — the offline substitute for proptest, see
//! DESIGN.md "Substitutions").  Invariants covered:
//!   * radix prefix cache: structural invariants + semantic equivalence to
//!     a brute-force prefix store under random workloads
//!   * block pool: refcount conservation under random alloc/retain/release
//!   * event queue: global time ordering under random schedules; calendar
//!     vs legacy-heap observational equivalence under heavy time ties
//!   * metrics: sketch-mode quantiles track exact histograms within the
//!     sketch's relative-error bound on random mixed distributions
//!   * simulator: conservation + determinism over random cluster configs
//!   * KV mixing: positionwise selection correctness on random geometries

use prefillshare::engine::config::{ClusterConfig, SystemKind};
use prefillshare::engine::sched::SchedPolicy;
use prefillshare::engine::sim::simulate;
use prefillshare::kvcache::block::BlockPool;
use prefillshare::kvcache::radix::RadixCache;
use prefillshare::metrics::{Histogram, MetricsMode};
use prefillshare::simtime::EventQueue;
use prefillshare::util::rng::Rng;
use prefillshare::workload::{generate_trace, react};

const CASES: u64 = 60;

// ---------------------------------------------------------------------------
// Radix cache vs a brute-force model
// ---------------------------------------------------------------------------

/// Brute-force reference: a set of inserted sequences; longest cached prefix
/// of q = max over stored sequences s of common_prefix(q, s) — valid only
/// while nothing has been evicted (we size capacity to avoid eviction).
fn brute_force_match(stored: &[Vec<u64>], q: &[u64]) -> usize {
    stored
        .iter()
        .map(|s| s.iter().zip(q).take_while(|(a, b)| a == b).count())
        .max()
        .unwrap_or(0)
}

#[test]
fn prop_radix_matches_brute_force_without_eviction() {
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0xabc);
        let mut cache = RadixCache::new(1_000_000); // never evicts
        let mut stored: Vec<Vec<u64>> = Vec::new();
        for _ in 0..rng.range(3, 30) {
            // Derive from an existing sequence (shared prefixes) or fresh.
            let seq: Vec<u64> = if !stored.is_empty() && rng.bool(0.6) {
                let base = rng.choose(&stored).clone();
                let cut = rng.range(0, base.len() + 1);
                let mut s = base[..cut].to_vec();
                for _ in 0..rng.range(1, 20) {
                    s.push(rng.range(0, 6) as u64);
                }
                s
            } else {
                (0..rng.range(1, 40)).map(|_| rng.range(0, 6) as u64).collect()
            };
            cache.insert(&seq);
            stored.push(seq);

            // Probe with random queries.
            for _ in 0..3 {
                let q: Vec<u64> = if rng.bool(0.7) {
                    let base = rng.choose(&stored).clone();
                    let cut = rng.range(0, base.len() + 1);
                    let mut s = base[..cut].to_vec();
                    for _ in 0..rng.range(0, 6) {
                        s.push(rng.range(0, 6) as u64);
                    }
                    s
                } else {
                    (0..rng.range(1, 30)).map(|_| rng.range(0, 6) as u64).collect()
                };
                if q.is_empty() {
                    continue;
                }
                let h = cache.match_prefix(&q);
                let want = brute_force_match(&stored, &q);
                assert_eq!(h.matched_tokens, want, "case {case}, q {q:?}");
                cache.unlock(&h);
            }
            cache.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
        }
    }
}

#[test]
fn prop_radix_capacity_never_exceeded_under_eviction() {
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0xdef);
        let cap = rng.range(20, 200);
        let mut cache = RadixCache::new(cap);
        for _ in 0..60 {
            let seq: Vec<u64> =
                (0..rng.range(1, 50)).map(|_| rng.range(0, 8) as u64).collect();
            cache.insert(&seq);
            assert!(
                cache.resident_tokens() <= cap,
                "case {case}: resident {} > cap {cap}",
                cache.resident_tokens()
            );
            cache.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
        }
    }
}

#[test]
fn prop_radix_match_insert_roundtrip() {
    // `insert` + `match_prefix` round-trip arbitrary token sequences: a
    // just-inserted sequence must fully match (capacity sized to never
    // evict), and the read-only `peek_prefix` must agree with the pinning
    // lookup everywhere.
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x666);
        let mut cache = RadixCache::new(1_000_000);
        let mut stored: Vec<Vec<u64>> = Vec::new();
        for _ in 0..rng.range(2, 25) {
            let seq: Vec<u64> = if !stored.is_empty() && rng.bool(0.5) {
                let base = rng.choose(&stored).clone();
                let cut = rng.range(0, base.len() + 1);
                let mut s = base[..cut].to_vec();
                for _ in 0..rng.range(1, 15) {
                    s.push(rng.range(0, 5) as u64);
                }
                s
            } else {
                (0..rng.range(1, 30)).map(|_| rng.range(0, 5) as u64).collect()
            };
            cache.insert(&seq);
            stored.push(seq.clone());
            let h = cache.match_prefix(&seq);
            assert_eq!(h.matched_tokens, seq.len(), "case {case}: roundtrip lost tokens");
            cache.unlock(&h);
            for probe in &stored {
                let h = cache.match_prefix(probe);
                assert_eq!(
                    cache.peek_prefix(probe),
                    h.matched_tokens,
                    "case {case}: peek/match disagree"
                );
                cache.unlock(&h);
            }
            cache.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
        }
    }
}

#[test]
fn prop_radix_eviction_never_removes_locked_nodes() {
    // Under sustained eviction pressure, every token of a locked (in-flight)
    // path stays resident and the capacity bound still holds.
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x777);
        let cap = rng.range(40, 160);
        let mut cache = RadixCache::new(cap);
        // Pin a few sequences, as in-flight prefills would.
        let mut pinned = Vec::new();
        for p in 0..rng.range(1, 4) {
            let seq: Vec<u64> = (0..rng.range(4, 12))
                .map(|i| case * 100_000 + (p * 1000 + i) as u64)
                .collect();
            cache.insert(&seq);
            let h = cache.match_prefix(&seq);
            assert_eq!(h.matched_tokens, seq.len());
            pinned.push((seq, h));
        }
        // Churn with evicting inserts the whole time.
        for _ in 0..80 {
            let seq: Vec<u64> = (0..rng.range(3, 25)).map(|_| rng.range(0, 30) as u64).collect();
            cache.insert(&seq);
            assert!(
                cache.resident_tokens() <= cap,
                "case {case}: resident {} > cap {cap}",
                cache.resident_tokens()
            );
            for (seq, _) in &pinned {
                assert_eq!(
                    cache.peek_prefix(seq),
                    seq.len(),
                    "case {case}: locked extent partially evicted"
                );
            }
        }
        for (_, h) in &pinned {
            cache.unlock(h);
        }
        cache.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

#[test]
fn prop_radix_pinned_extents_survive_eviction_pressure() {
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x111);
        let mut cache = RadixCache::new(64);
        let pinned: Vec<u64> = (0..32).map(|i| 1000 + i).collect();
        cache.insert(&pinned);
        let h = cache.match_prefix(&pinned);
        assert_eq!(h.matched_tokens, 32);
        // Hammer with inserts that force eviction.
        for _ in 0..40 {
            let seq: Vec<u64> = (0..rng.range(5, 30))
                .map(|_| rng.range(0, 50) as u64)
                .collect();
            cache.insert(&seq);
        }
        let h2 = cache.match_prefix(&pinned);
        assert_eq!(h2.matched_tokens, 32, "case {case}: pinned extent evicted");
        cache.unlock(&h);
        cache.unlock(&h2);
        cache.check_invariants().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Block pool
// ---------------------------------------------------------------------------

#[test]
fn prop_block_pool_conservation() {
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x222);
        let cap = rng.range(8, 128);
        let mut pool = BlockPool::new(cap, 16);
        let mut held: Vec<u32> = Vec::new();
        for _ in 0..300 {
            match rng.range(0, 3) {
                0 => {
                    let n = rng.range(1, 5);
                    if let Some(blocks) = pool.alloc(n) {
                        held.extend(blocks);
                    }
                }
                1 if !held.is_empty() => {
                    let idx = rng.range(0, held.len());
                    let b = held.swap_remove(idx);
                    pool.release(b);
                }
                2 if !held.is_empty() => {
                    let b = *rng.choose(&held);
                    pool.retain(b);
                    held.push(b);
                }
                _ => {}
            }
            pool.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
            assert!(pool.used_blocks() + pool.free_blocks() == cap);
        }
    }
}

// ---------------------------------------------------------------------------
// Event queue
// ---------------------------------------------------------------------------

#[test]
fn prop_event_queue_time_monotone() {
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x333);
        let mut q = EventQueue::new();
        for i in 0..rng.range(1, 500) {
            q.schedule(rng.range(0, 10_000) as u64, i);
        }
        let mut last = 0u64;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last, "case {case}");
            assert_eq!(t, q.now());
            last = t;
        }
    }
}

#[test]
fn prop_calendar_and_legacy_queues_agree_exactly() {
    // The calendar queue must be observationally identical to the legacy
    // `BinaryHeap` baseline: the same (time, payload) stream under random
    // interleavings of schedule bursts and pops.  Times are drawn from a
    // tiny range so bursts pile many events onto the exact same tick —
    // the (time, seq) FIFO tie-break is where the two implementations
    // could most plausibly diverge.
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x888);
        let mut cal = EventQueue::new();
        let mut leg = EventQueue::legacy();
        let mut next_payload = 0u64;
        for _ in 0..rng.range(50, 400) {
            if rng.bool(0.6) || cal.is_empty() {
                let at = cal.now() + rng.range(0, 8) as u64;
                for _ in 0..rng.range(1, 6) {
                    cal.schedule(at, next_payload);
                    leg.schedule(at, next_payload);
                    next_payload += 1;
                }
            } else {
                assert_eq!(cal.pop(), leg.pop(), "case {case}");
                assert_eq!(cal.now(), leg.now(), "case {case}");
            }
            assert_eq!(cal.len(), leg.len(), "case {case}");
        }
        loop {
            let (a, b) = (cal.pop(), leg.pop());
            assert_eq!(a, b, "case {case}: drain diverged");
            if a.is_none() {
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Metrics: sketch vs exact
// ---------------------------------------------------------------------------

#[test]
fn prop_sketch_quantiles_track_exact_histograms() {
    // Sketch-mode histograms promise: exact count/mean/max, and quantiles
    // within the sketch's relative-error bound of the nearest-rank truth.
    // Random mixed distributions: zeros, heavy ties at one value, a
    // uniform body and a long multiplicative tail, over scales spanning
    // several decades.
    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x999);
        let mut exact = Histogram::with_mode(MetricsMode::Exact);
        let mut sketch = Histogram::with_mode(MetricsMode::Sketch);
        let n = rng.range(50, 2000);
        let scale = 10f64.powi(rng.range(0, 7) as i32 - 3);
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            let v = match rng.range(0, 4) {
                0 => 0.0,
                1 => scale,
                2 => rng.f64() * scale,
                _ => rng.f64() * rng.f64() * 100.0 * scale,
            };
            exact.record(v);
            sketch.record(v);
            vals.push(v);
        }
        assert_eq!(exact.len(), sketch.len(), "case {case}");
        assert_eq!(exact.max().to_bits(), sketch.max().to_bits(), "case {case}: max");
        let mean_tol = 1e-9 * exact.mean().abs().max(1.0);
        assert!(
            (exact.mean() - sketch.mean()).abs() <= mean_tol,
            "case {case}: mean {} vs {}",
            exact.mean(),
            sketch.mean()
        );
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let rank = (q * (n - 1) as f64).round() as usize;
            let truth = vals[rank];
            let est = sketch.quantile(q);
            assert!(
                (est - truth).abs() <= 0.02 * truth.abs() + 1e-9,
                "case {case}: q{q} est {est} truth {truth} (n {n})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Simulator-level properties
// ---------------------------------------------------------------------------

#[test]
fn prop_sim_conservation_over_random_configs() {
    for case in 0..12 {
        let mut rng = Rng::new(case ^ 0x444);
        let system = if rng.bool(0.5) { SystemKind::Baseline } else { SystemKind::PrefillShare };
        let mut cfg = ClusterConfig::paper_default(system);
        cfg.max_concurrent_sessions = rng.range(4, 120);
        cfg.max_decode_batch = rng.range(4, 64);
        cfg.prefill_kv_tokens = rng.range(10_000, 400_000);
        cfg.decode_kv_tokens = rng.range(10_000, 200_000);
        // Conservation must hold under every scheduler policy and chunking
        // granularity, not just FIFO.
        let policies = SchedPolicy::all();
        cfg.sched = policies[rng.range(0, policies.len())];
        cfg.chunk_tokens = rng.range(64, 1024);
        let rate = 0.5 + rng.f64() * 4.0;
        let sched = cfg.sched;
        let trace = generate_trace(&react(), rate, 60.0, case);
        let n = trace.sessions.len();
        let calls: usize = trace.sessions.iter().map(|s| s.calls.len()).sum();
        let r = simulate(cfg, trace);
        let tag = format!("case {case} ({system:?}, {sched:?})");
        assert_eq!(r.sessions_completed as usize, n, "{tag}");
        assert_eq!(r.metrics.requests_completed as usize, calls, "{tag}");
        assert!(r.prefix_hit_ratio >= 0.0 && r.prefix_hit_ratio <= 1.0);
        // hit+miss tokens must equal total prefill demand
        let demand = r.metrics.prefix_hit_tokens + r.metrics.prefix_miss_tokens;
        assert!(demand > 0);
        assert_eq!(r.metrics.prefix_miss_tokens, r.prefill_computed_tokens, "{tag}");
        // every job dispatched exactly once; chunks only ever add units
        assert_eq!(r.metrics.prefill_jobs as usize, calls, "{tag}");
        assert!(r.metrics.prefill_chunks >= r.metrics.prefill_jobs, "{tag}");
    }
}

// ---------------------------------------------------------------------------
// KV cache mixing
// ---------------------------------------------------------------------------

#[test]
fn prop_kv_mixing_positionwise() {
    use prefillshare::model::kv::KvCache;
    use prefillshare::runtime::manifest::ModelSpec;

    for case in 0..CASES {
        let mut rng = Rng::new(case ^ 0x555);
        let spec = ModelSpec {
            name: "p".into(),
            d_model: 8,
            n_layers: rng.range(1, 4),
            n_heads: rng.range(1, 4),
            d_head: 4,
            d_ff: 16,
            s_max: rng.range(4, 16),
            vocab: 259,
            n_params: 0,
            init_params_file: "/dev/null".into(),
            param_specs: vec![],
        };
        let len = rng.range(1, spec.s_max + 1);
        let mut a = KvCache::empty(&spec);
        let mut b = KvCache::empty(&spec);
        a.k.fill(1.0);
        a.v.fill(1.0);
        b.k.fill(2.0);
        b.v.fill(2.0);
        a.len = len;
        b.len = len;
        let n_base = rng.range(0, len + 1);
        let mix = KvCache::mixed(&a, &b, n_base).unwrap();
        // check each position row comes from the right source
        for l in 0..spec.n_layers {
            for h in 0..spec.n_heads {
                for p in 0..len {
                    let idx = (((l * spec.n_heads) + h) * spec.s_max + p) * spec.d_head;
                    let want = if p < n_base { 1.0 } else { 2.0 };
                    assert_eq!(mix.k[idx], want, "case {case} l{l} h{h} p{p}");
                }
            }
        }
    }
}

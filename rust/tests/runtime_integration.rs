//! Integration: artifacts -> PJRT -> model facade.
//!
//! The heavyweight invariant here is cross-program consistency: building a
//! context with the *prefill* artifact and continuing with the *decode*
//! artifact must give the same logits as running decode steps from scratch.
//! That is the contract every cache handoff in the serving layer relies on.
//!
//! Requires `make artifacts` (skipped gracefully if missing).

use std::rc::Rc;

use prefillshare::model::{ByteTokenizer, KvCache, LanguageModel, ParamSet, Sampler};
use prefillshare::runtime::XlaRuntime;
use prefillshare::util::rng::Rng;

fn runtime() -> Option<Rc<XlaRuntime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Rc::new(XlaRuntime::new(dir).expect("runtime")))
}

#[test]
fn manifest_loads_and_programs_enumerate() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.models.contains_key("tiny"));
    assert_eq!(rt.manifest.vocab.size, 259);
    let buckets = rt.manifest.prefill_buckets("tiny");
    assert!(buckets.contains(&32) && buckets.contains(&256), "{buckets:?}");
    assert_eq!(rt.manifest.decode_batches("tiny"), vec![1, 2, 4]);
}

#[test]
fn init_params_match_manifest_count() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.model("tiny").unwrap();
    let params = ParamSet::load_init(spec).unwrap();
    assert_eq!(params.num_elements(), spec.n_params);
    assert_eq!(params.len(), spec.param_specs.len());
}

#[test]
fn prefill_then_decode_equals_decode_only() {
    let Some(rt) = runtime() else { return };
    let lm = LanguageModel::with_init_params(rt, "tiny").unwrap();
    let tok = ByteTokenizer;
    let prompt = tok.encode("the quick brown fox");

    // Path A: prefill prompt[..n-1], decode prompt[n-1].
    let n = prompt.len();
    let (mut cache_a, _) = lm.prefill(&prompt[..n - 1]).unwrap();
    assert_eq!(cache_a.len, n - 1);
    let logits_a = lm.decode_step(&mut cache_a, prompt[n - 1], n - 1).unwrap();

    // Path B: decode every token from an empty cache.
    let mut cache_b = KvCache::empty(&lm.spec);
    let mut logits_b = Vec::new();
    for (i, &t) in prompt.iter().enumerate() {
        logits_b = lm.decode_step(&mut cache_b, t, i).unwrap();
    }

    assert_eq!(logits_a.len(), 259);
    let max_diff = logits_a
        .iter()
        .zip(&logits_b)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "prefill/decode mismatch: {max_diff}");
}

#[test]
fn bucket_selection_is_transparent() {
    // The same prompt must produce the same cache contents no matter which
    // padded bucket served it (padding invariance through the real stack).
    let Some(rt) = runtime() else { return };
    let lm = LanguageModel::with_init_params(rt, "tiny").unwrap();
    let tok = ByteTokenizer;
    let prompt = tok.encode("abcdefghij"); // 11 tokens -> bucket 32

    let (cache_small, logits_small) = lm.prefill(&prompt).unwrap();
    // Force the bigger bucket by padding the prompt artificially? No — use
    // bucket_for to confirm selection, then compare against a longer bucket
    // via a prompt that only fits it.
    assert_eq!(lm.bucket_for(prompt.len()).unwrap(), 32);

    // Rerun identical prompt; cache must be byte-identical (determinism).
    let (cache_again, logits_again) = lm.prefill(&prompt).unwrap();
    assert_eq!(cache_small.k, cache_again.k);
    assert_eq!(logits_small, logits_again);
}

#[test]
fn generation_is_deterministic_and_stops_at_capacity() {
    let Some(rt) = runtime() else { return };
    let lm = LanguageModel::with_init_params(rt, "tiny").unwrap();
    let tok = ByteTokenizer;
    let prompt = tok.encode("hello");
    let mut rng1 = Rng::new(0);
    let mut rng2 = Rng::new(0);
    let g1 = lm.generate(&prompt, 8, Sampler::Greedy, &mut rng1).unwrap();
    let g2 = lm.generate(&prompt, 8, Sampler::Greedy, &mut rng2).unwrap();
    assert_eq!(g1, g2);
    assert!(g1.len() <= 8);
}

#[test]
fn cross_model_cache_generation_runs() {
    // Base prefill + decode-module generation — the PrefillShare serve path.
    // Init params for base; "decode module" = same params with a small
    // perturbation via a second LanguageModel on the same weights (the
    // algorithmic accuracy tests live in the training driver; here we only
    // prove the data path composes).
    let Some(rt) = runtime() else { return };
    let base = LanguageModel::with_init_params(rt.clone(), "tiny").unwrap();
    let dec = LanguageModel::with_init_params(rt, "tiny").unwrap();
    let tok = ByteTokenizer;
    let prompt = tok.encode("shared context here");
    let n = prompt.len();

    let (mut cache, _) = base.prefill(&prompt[..n - 1]).unwrap();
    let mut rng = Rng::new(7);
    let out = dec
        .generate_from_cache(&mut cache, prompt[n - 1], 6, Sampler::Greedy, &mut rng)
        .unwrap();
    assert!(out.len() <= 6);
    // One decode step per emitted token (+1 if the loop ended on EOS, since
    // the EOS-producing step still wrote the input token's KV).
    assert!(cache.len >= n - 1 + out.len() && cache.len <= n + out.len());
}

//! Integration tests over the cluster simulator: cross-module behaviour the
//! unit tests can't see (workload -> router -> prefill/radix -> handoff ->
//! decode/staging -> metrics), plus the paper's qualitative claims as
//! executable assertions.

use prefillshare::costmodel::{LLAMA8B, QWEN14B};
use prefillshare::engine::config::{ClusterConfig, RoutingPolicy, SystemKind};
use prefillshare::engine::sim::{simulate, SimResult};
use prefillshare::workload::{generate_trace, react, reflexion, Trace};

fn trace(rate: f64, dur: f64, seed: u64) -> Trace {
    generate_trace(&react(), rate, dur, seed)
}

fn run(system: SystemKind, rate: f64, max_cc: usize) -> SimResult {
    let mut cfg = ClusterConfig::paper_default(system);
    cfg.max_concurrent_sessions = max_cc;
    simulate(cfg, trace(rate, 120.0, 0))
}

#[test]
fn conservation_all_arrivals_complete() {
    let t = trace(2.0, 120.0, 0);
    for system in [SystemKind::Baseline, SystemKind::PrefillShare] {
        let r = run(system, 2.0, 64);
        assert_eq!(r.sessions_completed as usize, t.sessions.len(), "{system:?}");
        assert_eq!(
            r.metrics.requests_completed as usize,
            t.sessions.iter().map(|s| s.calls.len()).sum::<usize>()
        );
        // every generated token is accounted
        let expect: u64 = t.sessions.iter().map(|s| s.total_output_tokens() as u64).sum();
        assert_eq!(r.metrics.generated.tokens, expect);
    }
}

#[test]
fn fig3_claim_prefillshare_dominates_at_high_load() {
    let base = run(SystemKind::Baseline, 6.0, 96);
    let ps = run(SystemKind::PrefillShare, 6.0, 96);
    assert!(
        ps.p95_session_latency < base.p95_session_latency / 2.0,
        "p95: ps {} vs base {}",
        ps.p95_session_latency,
        base.p95_session_latency
    );
    assert!(ps.throughput_tok_s > 1.2 * base.throughput_tok_s);
    assert!(ps.ttft_p95 < base.ttft_p95);
}

#[test]
fn fig3_claim_parity_at_low_load() {
    // "At low load, both systems achieve similar latency and throughput."
    let base = run(SystemKind::Baseline, 0.5, 64);
    let ps = run(SystemKind::PrefillShare, 0.5, 64);
    let rel = (base.mean_session_latency - ps.mean_session_latency).abs()
        / base.mean_session_latency;
    assert!(rel < 0.15, "low-load latency gap {rel}");
}

#[test]
fn fig4_claim_baseline_hit_ratio_collapses_prefillshare_flat() {
    let base_lo = run(SystemKind::Baseline, 8.0, 40);
    let base_hi = run(SystemKind::Baseline, 8.0, 160);
    let ps_lo = run(SystemKind::PrefillShare, 8.0, 40);
    let ps_hi = run(SystemKind::PrefillShare, 8.0, 160);
    assert!(
        base_hi.prefix_hit_ratio < base_lo.prefix_hit_ratio - 0.15,
        "baseline must degrade: {} -> {}",
        base_lo.prefix_hit_ratio,
        base_hi.prefix_hit_ratio
    );
    assert!(
        (ps_hi.prefix_hit_ratio - ps_lo.prefix_hit_ratio).abs() < 0.05,
        "prefillshare must stay flat: {} -> {}",
        ps_lo.prefix_hit_ratio,
        ps_hi.prefix_hit_ratio
    );
    assert!(ps_hi.prefix_hit_ratio > 0.85);
}

#[test]
fn staging_rollover_is_staging_not_cache_driven() {
    // At very high concurrency PrefillShare slows from KV staging while the
    // hit ratio is unchanged (paper: "driven by handoff-related pressure
    // rather than prefix cache inefficiency").
    let peak = run(SystemKind::PrefillShare, 8.0, 80);
    let over = run(SystemKind::PrefillShare, 8.0, 200);
    assert!(over.staging_events > peak.staging_events);
    assert!((over.prefix_hit_ratio - peak.prefix_hit_ratio).abs() < 0.03);
}

#[test]
fn routing_ablation_prefix_aware_wins() {
    for policy in [RoutingPolicy::RoundRobin, RoutingPolicy::Random] {
        let mut cfg = ClusterConfig::paper_default(SystemKind::PrefillShare);
        cfg.routing = policy;
        let worse = simulate(cfg, trace(3.0, 120.0, 0));
        let mut cfg = ClusterConfig::paper_default(SystemKind::PrefillShare);
        cfg.routing = RoutingPolicy::PrefixAware;
        let good = simulate(cfg, trace(3.0, 120.0, 0));
        assert!(
            good.prefix_hit_ratio > worse.prefix_hit_ratio + 0.2,
            "{policy:?}: {} vs {}",
            worse.prefix_hit_ratio,
            good.prefix_hit_ratio
        );
    }
}

#[test]
fn qwen14b_is_heavier_but_same_story() {
    let mut bcfg = ClusterConfig::for_llm(SystemKind::Baseline, QWEN14B);
    bcfg.max_concurrent_sessions = 96;
    let mut pcfg = ClusterConfig::for_llm(SystemKind::PrefillShare, QWEN14B);
    pcfg.max_concurrent_sessions = 96;
    let base = simulate(bcfg, trace(4.0, 120.0, 0));
    let ps = simulate(pcfg, trace(4.0, 120.0, 0));
    assert!(ps.p95_session_latency < base.p95_session_latency);
    assert!(ps.prefix_hit_ratio > base.prefix_hit_ratio);

    // Same workload on the lighter backbone is faster end to end.
    let mut lcfg = ClusterConfig::for_llm(SystemKind::PrefillShare, LLAMA8B);
    lcfg.max_concurrent_sessions = 96;
    let llama = simulate(lcfg, trace(4.0, 120.0, 0));
    assert!(llama.mean_session_latency < ps.mean_session_latency);
}

#[test]
fn reflexion_contexts_are_heavier_than_react() {
    let r = generate_trace(&react(), 2.0, 100.0, 0);
    let x = generate_trace(&reflexion(), 2.0, 100.0, 0);
    let mean = |t: &Trace| {
        t.sessions
            .iter()
            .map(|s| s.final_context_len(t.workload.sys_prompt_tokens))
            .sum::<usize>() as f64
            / t.sessions.len() as f64
    };
    assert!(mean(&x) > mean(&r) * 1.1);
}

#[test]
fn memory_eq_prefill_burden_grows_with_n_models_only_for_baseline() {
    let rows = prefillshare::engine::experiments::memory_scaling(0);
    // ratio baseline/prefillshare grows with N (Eq. 8 vs 9)
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    let r1 = first.1 as f64 / first.2.max(1) as f64;
    let r8 = last.1 as f64 / last.2.max(1) as f64;
    assert!(r8 > r1 * 1.5, "N-scaling: {r1} -> {r8}");
}

#[test]
fn determinism_across_identical_configs() {
    let a = run(SystemKind::PrefillShare, 3.0, 64);
    let b = run(SystemKind::PrefillShare, 3.0, 64);
    assert_eq!(a.p95_session_latency.to_bits(), b.p95_session_latency.to_bits());
    assert_eq!(a.staging_events, b.staging_events);
    assert_eq!(a.prefill_computed_tokens, b.prefill_computed_tokens);
}

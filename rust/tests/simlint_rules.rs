//! simlint integration suite: one positive (violation caught) and one
//! negative (waiver honored / allowlist passes) fixture per rule, the
//! CacheStore-eviction bug mirrored as a fixture, and the gate itself —
//! the real tree must lint clean.

use prefillshare::lint::{analyze_source, repo_root, run};

/// A path inside the simulation-state scope (R1/R4 apply there).
const SIM_PATH: &str = "rust/src/engine/sim/fixture.rs";
/// A path outside every scoped rule's target set.
const PLAIN_PATH: &str = "rust/src/training/fixture.rs";

fn rules_of(findings: &[prefillshare::lint::Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// R1: HashMap/HashSet iteration in simulation state
// ---------------------------------------------------------------------------

/// The exact bug simlint was built to catch: `CacheStore::put` in
/// `engine/real.rs` selected its eviction victim by iterating a
/// `HashMap` with `min_by_key`, so a last-use-tick tie was broken by
/// `RandomState` enumeration order.  This fixture mirrors that shape,
/// including the rustfmt-split method chain.
const CACHE_STORE_BUG: &str = "\
struct CacheStore {
    entries: std::collections::HashMap<(u64, usize), (usize, u64)>,
}
impl CacheStore {
    fn victim(&self, key: (u64, usize)) -> Option<(u64, usize)> {
        self.entries
            .iter()
            .filter(|(k, _)| **k != key)
            .min_by_key(|(_, (_, t))| *t)
            .map(|(k, _)| *k)
    }
}
";

#[test]
fn r1_catches_the_cache_store_eviction_bug() {
    let (findings, _) = analyze_source(SIM_PATH, CACHE_STORE_BUG);
    assert!(
        findings.iter().any(|f| f.rule == "R1" && f.msg.contains("entries.iter")),
        "HashMap iteration behind a split chain must be flagged: {findings:?}"
    );
    // Same shape in real.rs itself — the file the bug lived in is scoped.
    let (findings, _) = analyze_source("rust/src/engine/real.rs", CACHE_STORE_BUG);
    assert!(rules_of(&findings).contains(&"R1"), "{findings:?}");
    // Outside simulation state the same code is allowed.
    let (findings, _) = analyze_source(PLAIN_PATH, CACHE_STORE_BUG);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn r1_allows_point_lookups_and_btreemap() {
    let fixed = "\
struct CacheStore {
    entries: std::collections::BTreeMap<(u64, usize), (usize, u64)>,
    index: std::collections::HashMap<u64, usize>,
}
impl CacheStore {
    fn get(&self, k: u64) -> Option<usize> {
        self.index.get(&k).copied()
    }
    fn victims(&self) -> Vec<(u64, usize)> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }
}
";
    let (findings, _) = analyze_source(SIM_PATH, fixed);
    assert!(
        findings.is_empty(),
        "BTreeMap iteration and HashMap point lookups are fine: {findings:?}"
    );
}

#[test]
fn r1_waiver_is_honored_and_needs_a_reason() {
    let waived = "\
struct S { m: std::collections::HashMap<u64, u64> }
fn f(s: &S) -> u64 {
    // simlint: allow(R1) summed values are order-independent
    s.m.values().sum()
}
";
    let (findings, waived_n) = analyze_source(SIM_PATH, waived);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(waived_n, 1);

    let reasonless = "\
struct S { m: std::collections::HashMap<u64, u64> }
fn f(s: &S) -> u64 {
    // simlint: allow(R1)
    s.m.values().sum()
}
";
    let (findings, _) = analyze_source(SIM_PATH, reasonless);
    assert!(
        findings.iter().any(|f| f.rule == "WAIVER"),
        "a waiver without a reason must itself be a finding: {findings:?}"
    );
}

// ---------------------------------------------------------------------------
// R2: wall clock outside timing shims
// ---------------------------------------------------------------------------

#[test]
fn r2_violation_waiver_and_allowlist() {
    let src = "fn f() -> std::time::Instant { std::time::Instant::now() }\n";
    let (findings, _) = analyze_source(SIM_PATH, src);
    assert!(rules_of(&findings).contains(&"R2"), "{findings:?}");

    let waived = "// simlint: allow-file(R2) fixture measures its own harness\nfn f() -> std::time::Instant { std::time::Instant::now() }\n";
    let (findings, waived_n) = analyze_source(SIM_PATH, waived);
    assert!(findings.is_empty(), "{findings:?}");
    assert!(waived_n >= 1);

    // The bench shim is allowlisted: clean with no waiver at all.
    let (findings, waived_n) = analyze_source("rust/src/util/bench.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(waived_n, 0);
}

// ---------------------------------------------------------------------------
// R3: threads/atomics outside the run_sweep runner
// ---------------------------------------------------------------------------

#[test]
fn r3_violation_and_allowlist() {
    let src = "\
use std::sync::atomic::{AtomicUsize, Ordering};
fn f() {
    static N: AtomicUsize = AtomicUsize::new(0);
    std::thread::spawn(move || N.fetch_add(1, Ordering::SeqCst));
}
";
    let (findings, _) = analyze_source(PLAIN_PATH, src);
    assert!(rules_of(&findings).contains(&"R3"), "{findings:?}");

    // The sweep runner is the one sanctioned concurrency site.
    let (findings, _) = analyze_source("rust/src/engine/experiments.rs", src);
    assert!(findings.iter().all(|f| f.rule != "R3"), "{findings:?}");
}

// ---------------------------------------------------------------------------
// R4: float accumulation into conservation counters
// ---------------------------------------------------------------------------

#[test]
fn r4_violation_boundary_idiom_and_waiver() {
    let bad = "\
struct Metrics { handoff_bytes: f64 }
fn f(m: &mut Metrics, tokens: usize, per: f64) {
    m.handoff_bytes += tokens as f64 * per;
}
";
    let (findings, _) = analyze_source(SIM_PATH, bad);
    assert!(rules_of(&findings).contains(&"R4"), "{findings:?}");

    // f64 at the boundary, integer storage: the sanctioned idiom.
    let good = "\
struct Metrics { handoff_bytes: u64 }
fn f(m: &mut Metrics, tokens: usize, per: f64) {
    m.handoff_bytes += (tokens as f64 * per) as u64;
}
";
    let (findings, _) = analyze_source(SIM_PATH, good);
    assert!(findings.is_empty(), "{findings:?}");

    let waived = "\
// simlint: allow(R4) fixture models an analog gauge, not a conserved total
struct Gauge { drift_bytes: f64 }
";
    let (findings, waived_n) = analyze_source(SIM_PATH, waived);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(waived_n, 1);
}

// ---------------------------------------------------------------------------
// The gate: the real tree is clean, and the report is stable
// ---------------------------------------------------------------------------

#[test]
fn real_tree_lints_clean() {
    let report = run(&repo_root()).expect("simlint pass over the real tree");
    assert!(
        report.is_clean(),
        "the tree must carry zero unwaived findings:\n{}",
        report.render()
    );
    assert!(report.files_scanned > 10, "walked {} files", report.files_scanned);
    // The documented exceptions exist: at least the real-execution
    // engine's allow-file(R2) waiver must have suppressed something.
    assert!(report.waived >= 1, "expected at least one waived finding");
}

#[test]
fn report_is_deterministic_and_sorted() {
    let a = run(&repo_root()).expect("simlint pass");
    let b = run(&repo_root()).expect("simlint pass");
    assert_eq!(a.render(), b.render());
    let keys: Vec<_> = a.findings.iter().map(|f| (f.file.clone(), f.line, f.rule)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "findings must come out sorted");
}

//! Integration: the training driver over the real AOT train-step artifacts.
//! Verifies the cache-conditioned fine-tuning algorithm end-to-end from
//! rust: losses decrease, the CC view really consumes the base cache, the
//! base stays frozen, and the generation evaluator runs the true
//! shared-prefill data path.  (Skipped when artifacts are absent.)

use std::rc::Rc;

use prefillshare::model::{LanguageModel, ParamSet};
use prefillshare::runtime::XlaRuntime;
use prefillshare::training::data::{build_dataset, Task};
use prefillshare::training::driver::{OptState, Trainer};
use prefillshare::training::evalgen::eval_accuracy;
use prefillshare::util::rng::Rng;

fn runtime() -> Option<Rc<XlaRuntime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Rc::new(XlaRuntime::new(dir).expect("runtime")))
}

#[test]
fn full_ft_loss_decreases() {
    let Some(rt) = runtime() else { return };
    let trainer = Trainer::new(rt, "tiny").unwrap();
    let data = build_dataset(Task::Arith, 256, 16, 0);
    let mut params = ParamSet::load_init(&trainer.spec).unwrap();
    let mut opt = OptState::new(&params);
    let mut rng = Rng::new(0);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..12 {
        let exs = trainer.sample_batch(&data.train, &mut rng);
        let batch = trainer.assemble(&exs).unwrap();
        last = trainer.step_full(&mut params, &mut opt, &batch, 2e-3).unwrap();
        first.get_or_insert(last);
    }
    assert!(last < first.unwrap() * 0.8, "{first:?} -> {last}");
}

#[test]
fn cc_ft_loss_decreases_and_base_is_input_only() {
    let Some(rt) = runtime() else { return };
    let trainer = Trainer::new(rt, "tiny").unwrap();
    let data = build_dataset(Task::Toolcall, 256, 16, 1);
    let base = ParamSet::load_init(&trainer.spec).unwrap();
    let base_snapshot = base.clone();
    let mut dec = base.clone();
    let mut opt = OptState::new(&dec);
    let mut rng = Rng::new(1);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..12 {
        let exs = trainer.sample_batch(&data.train, &mut rng);
        let batch = trainer.assemble(&exs).unwrap();
        last = trainer.step_cc(&base, &mut dec, &mut opt, &batch, 2e-3).unwrap();
        first.get_or_insert(last);
    }
    assert!(last < first.unwrap() * 0.8, "{first:?} -> {last}");
    // The frozen prefill module must be bit-identical after training.
    assert_eq!(base.l2_distance(&base_snapshot), 0.0);
    // ...while the decode module genuinely moved.
    assert!(dec.l2_distance(&base_snapshot) > 0.0);
}

#[test]
fn cc_eval_view_matches_full_view_when_params_equal() {
    // With dec == base, the cache-conditioned eval loss must equal the
    // full-FT eval loss (the mixed cache is then the model's own cache).
    let Some(rt) = runtime() else { return };
    let trainer = Trainer::new(rt, "tiny").unwrap();
    let data = build_dataset(Task::Arith, 64, 8, 2);
    let params = ParamSet::load_init(&trainer.spec).unwrap();
    let mut rng = Rng::new(2);
    let exs = trainer.sample_batch(&data.train, &mut rng);
    let batch = trainer.assemble(&exs).unwrap();
    let lf = trainer.eval_full(&params, &batch).unwrap();
    let lc = trainer.eval_cc(&params, &params, &batch).unwrap();
    assert!((lf - lc).abs() < 2e-3, "full {lf} vs cc {lc}");
}

#[test]
fn eval_accuracy_runs_all_sharing_ratios() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.model("tiny").unwrap().clone();
    let base = LanguageModel::new(rt.clone(), "tiny", ParamSet::load_init(&spec).unwrap()).unwrap();
    let model = LanguageModel::new(rt, "tiny", ParamSet::load_init(&spec).unwrap()).unwrap();
    let data = build_dataset(Task::Arith, 32, 4, 3);
    for ratio in [0.0, 0.5, 1.0] {
        let r = eval_accuracy(&base, &model, &data.test, ratio, 8).unwrap();
        assert_eq!(r.total, 4);
        // Untrained models should not magically solve arithmetic.
        assert!(r.accuracy() <= 0.5, "ratio {ratio}");
    }
}

#[test]
fn batch_assembly_layout() {
    let Some(rt) = runtime() else { return };
    let trainer = Trainer::new(rt, "tiny").unwrap();
    let data = build_dataset(Task::Transform, 64, 8, 4);
    let exs: Vec<&_> = data.train.iter().take(trainer.batch_size()).collect();
    let batch = trainer.assemble(&exs).unwrap();
    let toks = batch.tokens.as_i32().unwrap();
    let plen = batch.prompt_len.as_i32().unwrap();
    let tlen = batch.total_len.as_i32().unwrap();
    let seq = toks.len() / plen.len();
    for (b, ex) in exs.iter().enumerate() {
        let row = &toks[b * seq..(b + 1) * seq];
        assert_eq!(row[0], prefillshare::model::BOS);
        let p = plen[b] as usize;
        let t = tlen[b] as usize;
        assert!(p < t && t <= seq);
        assert_eq!(row[t - 1], prefillshare::model::EOS);
        // prompt bytes match
        let prompt_bytes: Vec<i32> = ex.prompt.bytes().map(|x| x as i32).collect();
        assert_eq!(&row[1..p], &prompt_bytes[..]);
        // padding after total_len
        for &x in &row[t..] {
            assert_eq!(x, prefillshare::model::PAD);
        }
    }
}

//! Statistical unit tests for `workload.rs`: the generated traces must
//! actually have the shape the specs promise — agent sequencing over
//! `NUM_AGENTS` models, lognormal token lengths landing on the configured
//! means, Poisson arrivals at the configured rate, and — since the DAG
//! generalization — dependency graphs whose topology statistics
//! (ready-set widths, ancestor-cut context lengths) match the template,
//! with the chain workloads staying **byte-identical** to the legacy flat
//! generator.  All seeded, with bounds ≥3σ wide so they are
//! deterministic-pass, not flaky.

use prefillshare::simtime::{secs, to_secs};
use prefillshare::util::rng::Rng;
use prefillshare::workload::{
    debate, fanout, generate_trace, mixed, react, reflexion, workload_by_name, workload_names,
    workload_registry, NUM_AGENTS,
};

#[test]
fn sessions_follow_num_agents_sequencing() {
    for spec in [react(), reflexion()] {
        assert_eq!(spec.agents.len(), NUM_AGENTS, "{}", spec.name);
        let t = generate_trace(&spec, 2.0, 80.0, 9);
        assert!(!t.sessions.is_empty());
        for s in &t.sessions {
            // Every turn invokes the full agent chain, in order.
            assert_eq!(s.calls.len(), spec.turns * NUM_AGENTS);
            assert!(s.is_chain(), "{} is the degenerate chain DAG", spec.name);
            for (i, c) in s.calls.iter().enumerate() {
                assert_eq!(c.model, spec.agents[i % NUM_AGENTS].model);
                assert_eq!(c.model, i % NUM_AGENTS, "agent chain must cycle 0..NUM_AGENTS");
            }
        }
    }
}

#[test]
fn workloads_resolve_by_name() {
    for name in ["react", "reflexion", "fanout", "debate", "mixed"] {
        assert_eq!(workload_by_name(name).unwrap().name, name);
        assert!(workload_names().split('|').any(|n| n == name), "`{name}` missing from names");
    }
    assert!(workload_by_name("does-not-exist").is_none());
    assert_eq!(workload_registry().len(), workload_names().split('|').count());
}

/// The chain-equivalence pin: the DAG-encoded `react`/`reflexion`
/// workloads must reproduce the pre-DAG flat generator *byte-for-byte* —
/// same arrivals, same init prompts, same per-call (model, out_tokens)
/// sequence, chain edges exactly.  The legacy generator is reimplemented
/// inline (its exact RNG discipline: one arrival stream, fork per
/// session, init then turn-major output draws).
#[test]
fn dag_chain_encoding_reproduces_the_legacy_flat_generator() {
    for spec in [react(), reflexion()] {
        let t = generate_trace(&spec, 2.0, 60.0, 42);

        let mut rng = Rng::new(42 ^ 0x5e551_0ad);
        let mut at = 0.0f64;
        let mut id = 0u64;
        let mut legacy: Vec<(u64, usize, Vec<(usize, usize)>)> = Vec::new();
        loop {
            at += rng.exp(2.0);
            if at >= 60.0 {
                break;
            }
            let mut srng = rng.fork(id);
            let init =
                srng.lognormal_mean_cv(spec.init_prompt_mean, spec.init_prompt_cv).round() as usize;
            let init = init.clamp(16, 4096);
            let mut calls = Vec::new();
            for _turn in 0..spec.turns {
                for a in &spec.agents {
                    let out = srng.lognormal_mean_cv(a.mean_out_tokens, a.cv).round() as usize;
                    calls.push((a.model, out.clamp(8, 1024)));
                }
            }
            legacy.push((secs(at), init, calls));
            id += 1;
        }

        assert_eq!(t.sessions.len(), legacy.len(), "{}: session count drifted", spec.name);
        for (s, (arrival, init, calls)) in t.sessions.iter().zip(&legacy) {
            assert_eq!(s.arrival, *arrival, "{}: arrival drifted", spec.name);
            assert_eq!(s.init_prompt_tokens, *init, "{}: init prompt drifted", spec.name);
            assert_eq!(s.calls.len(), calls.len());
            for (i, (node, &(model, out))) in s.calls.iter().zip(calls).enumerate() {
                assert_eq!(node.model, model, "{}: model drifted at call {i}", spec.name);
                assert_eq!(node.out_tokens, out, "{}: out_tokens drifted at call {i}", spec.name);
                let want: Vec<usize> = if i == 0 { vec![] } else { vec![i - 1] };
                assert_eq!(node.parents, want, "{}: chain edge drifted at call {i}", spec.name);
            }
        }
    }
}

#[test]
fn dag_traces_are_deterministic() {
    for spec in [fanout(), debate(), mixed()] {
        let a = generate_trace(&spec, 3.0, 60.0, 11);
        let b = generate_trace(&spec, 3.0, 60.0, 11);
        assert_eq!(a.sessions.len(), b.sessions.len(), "{}", spec.name);
        for (x, y) in a.sessions.iter().zip(&b.sessions) {
            assert_eq!(x.arrival, y.arrival, "{}", spec.name);
            assert_eq!(x.init_prompt_tokens, y.init_prompt_tokens, "{}", spec.name);
            assert_eq!(x.calls, y.calls, "{}: call graph diverged", spec.name);
        }
    }
}

/// Topology statistics over many sampled sessions: the ready-set width
/// distribution (nodes per topological wave) must match the template for
/// every session, and the ancestor-cut join semantics must put sibling
/// specialists on *identical* input contexts while the joiner's context
/// is the full turn.
#[test]
fn dag_topology_statistics() {
    // fanout: every session's waves are (planner, 3 specialists, joiner)
    // per turn; debate: (3 proposers, judge) per round.
    let cases: &[(_, &[usize])] = &[
        (fanout(), &[1, 3, 1, 1, 3, 1, 1, 3, 1]),
        (debate(), &[3, 1, 3, 1, 3, 1]),
    ];
    for (spec, want_waves) in cases {
        let t = generate_trace(spec, 3.0, 120.0, 4);
        assert!(t.sessions.len() > 200, "need a large sample");
        for s in &t.sessions {
            assert_eq!(s.wave_widths().as_slice(), *want_waves, "{}", spec.name);
        }
    }

    // Ancestor-cut context lengths on fanout: all three specialists of a
    // turn share one cut (=> one input context length), and the joiner's
    // cut adds exactly their three outputs.
    let spec = fanout();
    let t = generate_trace(&spec, 3.0, 120.0, 4);
    let sys = spec.sys_prompt_tokens;
    let a = spec.agents.len();
    for s in &t.sessions {
        for turn in 0..spec.turns {
            let base = turn * a;
            let c1 = s.input_context_len(sys, base + 1);
            assert_eq!(c1, s.input_context_len(sys, base + 2), "siblings share the cut");
            assert_eq!(c1, s.input_context_len(sys, base + 3), "siblings share the cut");
            let sibling_out: usize =
                (1..=3).map(|j| s.calls[base + j].out_tokens).sum();
            assert_eq!(
                s.input_context_len(sys, base + 4),
                c1 + sibling_out,
                "joiner context = sibling context + the three sibling outputs"
            );
        }
        // The final node's cut is every other node: its input context plus
        // its own output is the session's final context.
        let last = s.calls.len() - 1;
        assert_eq!(
            s.input_context_len(sys, last) + s.calls[last].out_tokens,
            s.final_context_len(sys)
        );
    }

    // Mixed blend: both shapes occur at roughly the configured weights.
    let t = generate_trace(&mixed(), 4.0, 200.0, 11);
    let chains = t.sessions.iter().filter(|s| s.is_chain()).count();
    let frac = chains as f64 / t.sessions.len() as f64;
    // Port-mirrored at this seed: 410/792 = 0.518; binomial σ ≈ 0.018.
    assert!((frac - 0.5).abs() < 0.1, "mixed blend fraction {frac}");
}

#[test]
fn lognormal_output_lengths_match_configured_means() {
    let spec = react();
    let t = generate_trace(&spec, 4.0, 500.0, 3);
    let n = t.sessions.len();
    assert!(n > 1500, "need a large sample, got {n}");

    for (ai, agent) in spec.agents.iter().enumerate() {
        let (sum, cnt) = t
            .sessions
            .iter()
            .flat_map(|s| s.calls.iter().enumerate())
            .filter(|(i, _)| i % NUM_AGENTS == ai)
            .fold((0usize, 0usize), |(sum, cnt), (_, call)| (sum + call.out_tokens, cnt + 1));
        let mean = sum as f64 / cnt as f64;
        let want = agent.mean_out_tokens;
        // ~6k samples, sd ≈ cv·mean/√n ≈ 0.4 tokens — 5% is ≥10σ.
        assert!(
            (mean - want).abs() < 0.05 * want,
            "agent `{}`: sampled mean {mean:.2} vs configured {want}",
            agent.name
        );
    }

    let init_mean: f64 =
        t.sessions.iter().map(|s| s.init_prompt_tokens as f64).sum::<f64>() / n as f64;
    assert!(
        (init_mean - spec.init_prompt_mean).abs() < 0.05 * spec.init_prompt_mean,
        "init prompt mean {init_mean:.1} vs {}",
        spec.init_prompt_mean
    );
}

#[test]
fn poisson_interarrivals_have_configured_rate() {
    for (rate, seed) in [(1.0, 5u64), (4.0, 6), (8.0, 7)] {
        let dur = 400.0;
        let t = generate_trace(&react(), rate, dur, seed);
        let n = t.sessions.len() as f64;

        // Arrival count ≈ rate·duration.
        let got = n / dur;
        assert!((got - rate).abs() < 0.15 * rate, "rate {rate}: sampled {got:.3}");

        // Gaps are exponential: mean 1/rate, coefficient of variation ~1.
        let arrivals: Vec<f64> = t.sessions.iter().map(|s| to_secs(s.arrival)).collect();
        let gaps: Vec<f64> = arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(gaps.iter().all(|&g| g >= 0.0), "arrivals must be ordered");
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!(
            (mean - 1.0 / rate).abs() < 0.15 / rate,
            "rate {rate}: gap mean {mean:.4}"
        );
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.15, "rate {rate}: gap CV {cv:.3} (want ~1)");
    }
}

//! Statistical unit tests for `workload.rs`: the generated traces must
//! actually have the shape the specs promise — agent-chain sequencing over
//! `NUM_AGENTS` models, lognormal token lengths landing on the configured
//! means, and Poisson arrivals at the configured rate.  All seeded, with
//! bounds ≥3σ wide so they are deterministic-pass, not flaky.

use prefillshare::simtime::to_secs;
use prefillshare::workload::{generate_trace, react, reflexion, workload_by_name, NUM_AGENTS};

#[test]
fn sessions_follow_num_agents_sequencing() {
    for spec in [react(), reflexion()] {
        assert_eq!(spec.agents.len(), NUM_AGENTS, "{}", spec.name);
        let t = generate_trace(&spec, 2.0, 80.0, 9);
        assert!(!t.sessions.is_empty());
        for s in &t.sessions {
            // Every turn invokes the full agent chain, in order.
            assert_eq!(s.calls.len(), spec.turns * NUM_AGENTS);
            for (i, c) in s.calls.iter().enumerate() {
                assert_eq!(c.model, spec.agents[i % NUM_AGENTS].model);
                assert_eq!(c.model, i % NUM_AGENTS, "agent chain must cycle 0..NUM_AGENTS");
            }
        }
    }
}

#[test]
fn workloads_resolve_by_name() {
    assert_eq!(workload_by_name("react").unwrap().name, "react");
    assert_eq!(workload_by_name("reflexion").unwrap().name, "reflexion");
    assert!(workload_by_name("does-not-exist").is_none());
}

#[test]
fn lognormal_output_lengths_match_configured_means() {
    let spec = react();
    let t = generate_trace(&spec, 4.0, 500.0, 3);
    let n = t.sessions.len();
    assert!(n > 1500, "need a large sample, got {n}");

    for (ai, agent) in spec.agents.iter().enumerate() {
        let (sum, cnt) = t
            .sessions
            .iter()
            .flat_map(|s| s.calls.iter().enumerate())
            .filter(|(i, _)| i % NUM_AGENTS == ai)
            .fold((0usize, 0usize), |(sum, cnt), (_, call)| (sum + call.out_tokens, cnt + 1));
        let mean = sum as f64 / cnt as f64;
        let want = agent.mean_out_tokens;
        // ~6k samples, sd ≈ cv·mean/√n ≈ 0.4 tokens — 5% is ≥10σ.
        assert!(
            (mean - want).abs() < 0.05 * want,
            "agent `{}`: sampled mean {mean:.2} vs configured {want}",
            agent.name
        );
    }

    let init_mean: f64 =
        t.sessions.iter().map(|s| s.init_prompt_tokens as f64).sum::<f64>() / n as f64;
    assert!(
        (init_mean - spec.init_prompt_mean).abs() < 0.05 * spec.init_prompt_mean,
        "init prompt mean {init_mean:.1} vs {}",
        spec.init_prompt_mean
    );
}

#[test]
fn poisson_interarrivals_have_configured_rate() {
    for (rate, seed) in [(1.0, 5u64), (4.0, 6), (8.0, 7)] {
        let dur = 400.0;
        let t = generate_trace(&react(), rate, dur, seed);
        let n = t.sessions.len() as f64;

        // Arrival count ≈ rate·duration.
        let got = n / dur;
        assert!((got - rate).abs() < 0.15 * rate, "rate {rate}: sampled {got:.3}");

        // Gaps are exponential: mean 1/rate, coefficient of variation ~1.
        let arrivals: Vec<f64> = t.sessions.iter().map(|s| to_secs(s.arrival)).collect();
        let gaps: Vec<f64> = arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(gaps.iter().all(|&g| g >= 0.0), "arrivals must be ordered");
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!(
            (mean - 1.0 / rate).abs() < 0.15 / rate,
            "rate {rate}: gap mean {mean:.4}"
        );
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.15, "rate {rate}: gap CV {cv:.3} (want ~1)");
    }
}

//! Minimal in-tree replacement for the `anyhow` error facade.
//!
//! The offline crate universe this repo builds in has no registry access
//! (see DESIGN.md "Substitutions" — same reason `serde`/`clap`/`rand` are
//! replaced in `src/util/`), so the subset of `anyhow` the codebase uses is
//! vendored here as a path dependency with the same crate name:
//!
//!   * `anyhow::Result<T>` / `anyhow::Error`
//!   * `anyhow!`, `bail!`, `ensure!`
//!   * `Context::{context, with_context}` on `Result` and `Option`
//!   * `?`-conversion from any `std::error::Error + Send + Sync + 'static`
//!
//! Semantics match the real crate for this subset: `Error` deliberately does
//! **not** implement `std::error::Error` (that is what makes the blanket
//! `From` impl coherent), `Display` shows the outermost message and `Debug`
//! shows the whole context chain.

use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamically typed error with a chain of context messages.
pub struct Error {
    /// Outermost message first; earlier entries wrap later ones.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or("unknown error"))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or("unknown error"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// `?`-conversion from standard error types (mirrors `anyhow::Error: From`).
/// Coherent because `Error` itself does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension trait (the `anyhow::Context` subset).
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_fail().context("loading config").unwrap_err();
        assert_eq!(e.to_string(), "loading config");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(7).context("never").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("plain {}", 5);
        assert_eq!(e.to_string(), "plain 5");
    }
}

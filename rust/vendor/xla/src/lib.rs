//! Stub of the `xla` PJRT bindings used by `src/runtime/` and `src/model/`.
//!
//! The real crate wraps XLA's PJRT C++ client (HLO-text loading, CPU-client
//! compilation, buffer execution).  That toolchain is not present in every
//! build environment, and the simulator / training-data / scheduler layers —
//! the bulk of the crate and all of its default tests — do not need it.
//! This stub keeps the API surface compiling; every entry point returns a
//! clear runtime error instead.  Paths that would reach PJRT are already
//! gated on `artifacts/manifest.json` existing, so tests skip gracefully.
//!
//! To run the real backend, replace the `xla` path dependency in
//! `rust/Cargo.toml` with the actual bindings crate; no call-site changes
//! are required (the stub mirrors the used signatures exactly).

use std::fmt;
use std::path::Path;

/// Error type for all stubbed operations.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: XLA/PJRT backend not available in this build \
                 (stub `xla` crate; see rust/vendor/xla/src/lib.rs)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold / yield.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal value (stub: retains nothing).
#[derive(Debug, Clone)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    /// Read the literal back as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation graph (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device-resident buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument buffers/literals.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle (stub).
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// The CPU client — the only device class the repo targets.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_cleanly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
